"""Compile-once / run-many: the paper's GMRES-style use case.

One sparsity pattern, many value sets (e.g. iterative solver steps or NN
weights updated across training): inspection/compile cost is paid once,
every later matrix with the same pattern reuses the staged executable.

  PYTHONPATH=src python examples/pattern_reuse.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import StagingOptions, synthesize, stage_spmv
from repro.core.staging import cache_info, clear_cache
from repro.core.vbr import VBR

clear_cache()
base = synthesize(4000, 4000, 40, 40, 300, block_sparsity=0.2, seed=0)
x = jnp.asarray(np.random.default_rng(0).standard_normal(4000), jnp.float32)

t0 = time.perf_counter()
kern = stage_spmv(base, StagingOptions(backend="grouped"))
y = kern(jnp.asarray(base.val), x)
y.block_until_ready()
first = time.perf_counter() - t0
print(f"first matrix: staged+compiled+ran in {first*1e3:.1f} ms")

# 20 more matrices with the same pattern (solver iterations)
t0 = time.perf_counter()
rng = np.random.default_rng(1)
for i in range(20):
    m = VBR(**{**base.__dict__})
    m.val = rng.standard_normal(base.stored_nnz).astype(np.float32)
    k = stage_spmv(m, StagingOptions(backend="grouped"))  # cache hit
    k(jnp.asarray(m.val), x).block_until_ready()
rest = (time.perf_counter() - t0) / 20
print(f"20 same-pattern matrices: {rest*1e3:.1f} ms each "
      f"({first/rest:.0f}x faster than first)")
print("cache:", cache_info())

# ----------------------------------------------------------------------- #
# tune-once / run-forever: backend='autotune' measures the candidates once
# and persists the winning plan on disk keyed by the structure hash, so a
# SECOND PROCESS staging this pattern skips the search entirely
# (see docs/architecture.md and benchmarks/bench_autotune.py).
# ----------------------------------------------------------------------- #
from repro.core.autotune import autotune_stats  # noqa: E402

t0 = time.perf_counter()
kern_auto = stage_spmv(base, StagingOptions(backend="autotune"))
kern_auto(jnp.asarray(base.val), x).block_until_ready()
print(f"autotuned staging: {(time.perf_counter()-t0)*1e3:.1f} ms, "
      f"stats={autotune_stats()}")
