"""Batched serving with a KV cache (prefill once, decode many).

  PYTHONPATH=src python examples/serve_blockwise.py --arch llama3.2-3b
"""
import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.engine import ServeEngine

    cfg = get_config(args.arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=args.prompt_len + args.gen)

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    kwargs = {}
    if cfg.is_encdec:
        kwargs["src_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, 16, cfg.frontend_dim)
        )
    out, stats = engine.generate(
        prompts, max_new_tokens=args.gen, temperature=args.temperature, **kwargs
    )
    print(f"{args.arch}: generated {out.shape[0]}x{args.gen} tokens; "
          f"prefill {stats['prefill_s']*1e3:.0f} ms, "
          f"decode {stats['tokens_per_s']:.1f} tok/s")
    print("sample:", out[0, args.prompt_len : args.prompt_len + 12].tolist())


if __name__ == "__main__":
    main()
