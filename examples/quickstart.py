"""Quickstart: stage a blocked SpMV/SpMM the SABLE way.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import synthesize, stage_spmv, stage_spmm, StagingOptions
from repro.core.vbr import structure_hash

# 1. a sparse matrix with block structure, stored in VBR
#    (2000x2000, 20x20 grid, 60 mostly-dense blocks, 20% zeros inside)
vbr = synthesize(2000, 2000, 20, 20, 60, block_sparsity=0.2, seed=0)
print(f"matrix: {vbr.shape}, {vbr.num_blocks} blocks, "
      f"{vbr.stored_nnz:,} stored values, pattern {structure_hash(vbr)}")

# 2. Stage 0/1: inspect the indirection arrays, specialize the kernel
kern = stage_spmv(vbr, StagingOptions(backend="grouped"))
print(f"staged: backend={kern.backend}, {len(kern.classes)} shape classes, "
      f"stage0 {kern.stage0_time*1e3:.1f} ms")

# 3. Stage 2: run — only the VALUES and x are runtime inputs
x = jnp.asarray(np.random.default_rng(0).standard_normal(2000), jnp.float32)
y = kern(jnp.asarray(vbr.val), x)
ref = vbr.to_dense() @ np.asarray(x)
print("spmv max err vs densify-oracle:", float(np.abs(np.asarray(y) - ref).max()))

# 4. same pattern, different values -> the compiled executable is reused
vbr.val = vbr.val * 3.0
y2 = kern(jnp.asarray(vbr.val), x)
print("3x values -> 3x result:",
      bool(np.allclose(np.asarray(y2), 3 * np.asarray(y), rtol=1e-3, atol=1e-3)))

# 5. SpMM over the same structure (paper Section IV-C)
X = jnp.asarray(np.random.default_rng(1).standard_normal((2000, 64)), jnp.float32)
kern_mm = stage_spmm(vbr, 64, StagingOptions(backend="grouped"))
Y = kern_mm(jnp.asarray(vbr.val), X)
print("spmm out:", Y.shape)
