"""End-to-end training driver: a ~100M-param LM for a few hundred steps.

Default runs a reduced-but-real config on CPU; scale steps/size with flags.

  PYTHONPATH=src python examples/train_e2e.py --steps 300 --d-model 512 \
      --layers 8 --ckpt /tmp/e2e_ckpt

Demonstrates: data pipeline -> pjit'd train step -> async checkpoints ->
preemption-safe resume (rerun the same command: it resumes).
"""
import argparse
import sys

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=1024)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sable", action="store_true",
                    help="SABLE block-sparse FFN weights")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    from repro.models.config import (
        LayerSpec, ModelConfig, SableConfig, uniform_groups,
    )
    from repro.models import init_params
    from repro.models.config import param_count
    from repro.data.pipeline import SyntheticDataset
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.optim.schedule import cosine_schedule
    from repro.train.loop import TrainLoop
    from repro.train.step import make_train_step

    cfg = ModelConfig(
        name="e2e",
        family="dense",
        d_model=args.d_model,
        n_heads=max(args.d_model // 64, 1),
        n_kv_heads=max(args.d_model // 128, 1),
        head_dim=64,
        d_ff=args.d_ff,
        vocab_size=args.vocab,
        groups=uniform_groups(args.layers, LayerSpec()),
        compute_dtype="float32",
        sable=SableConfig(block_m=64, block_n=64, density=0.4) if args.sable
        else None,
    )
    print(f"model: {param_count(cfg)/1e6:.1f}M params "
          f"({'SABLE-sparse FFN' if args.sable else 'dense'})")

    params = init_params(cfg, jax.random.PRNGKey(0))
    oc = AdamWConfig(lr=args.lr)
    opt = adamw_init(params, oc)
    sched = lambda s: cosine_schedule(s, args.lr, 20, args.steps)
    step = jax.jit(make_train_step(cfg, oc, schedule=sched))
    ds = SyntheticDataset(cfg.vocab_size, args.seq, args.batch, seed=0)

    loop = TrainLoop(
        lambda p, o, b, i: step(p, o, b, jnp.int32(i)),
        ds,
        ckpt_dir=args.ckpt,
        ckpt_every=100,
    )
    if args.ckpt:
        params, opt, resumed = loop.maybe_restore(params, opt)
        if resumed:
            print(f"resumed from step {loop.step}")
    params, opt, metrics = loop.run(params, opt, args.steps, log_every=20)
    print(f"done at step {loop.step}: loss {float(metrics['loss']):.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
