"""Sharded staged SpMV/SpMM scaling over 1/2/4/8 forced host devices.

The paper's parallel results (up to ~7x on 8 threads) split staged block
work across workers; the sharded staging subsystem does the same split
across a JAX device mesh.  A normal CPU process sees ONE device, so the
measurement runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and stages the same
structure over 1/2/4/8-device meshes.  Forced host devices share the
physical cores, so on a 1-core container wall-clock SPEEDUP is not
expected — the row's ``derived`` field carries the partition balance
(``imbalance``, the quantity that bounds real-hardware scaling) next to
the measured time.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import csv_row

_CHILD = """
import json, numpy as np, jax, jax.numpy as jnp
from repro.core import vbr as vbrlib
from repro.core.staging import stage_spmv, stage_spmm
from repro.launch.mesh import make_staging_mesh
from benchmarks.common import timeit

quick = {quick}
n = 600 if quick else 2000
iters = 3 if quick else 8
rows = []
for rs, cs, nb in ([(24, 24, 90)] if quick else [(30, 30, 120), (80, 80, 900)]):
    v = vbrlib.synthesize(n, n, rs, cs, nb, 0.2, False, seed=nb)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    X = jnp.asarray(rng.standard_normal((n, 16)).astype(np.float32))
    val = jnp.asarray(v.val)
    for shards in (1, 2, 4, 8):
        mesh = make_staging_mesh(shards)
        kv = stage_spmv(v, mesh=mesh)
        tv = timeit(kv, val, x, warmup=2, iters=iters)
        km = stage_spmm(v, 16, mesh=mesh)
        tm = timeit(km, val, X, warmup=2, iters=iters)
        rows.append({{
            "matrix": f"Matrix_{{rs}}_{{cs}}_{{nb}}",
            "shards": shards,
            "spmv_s": tv,
            "spmm_s": tm,
            "imbalance": kv.imbalance(),
        }})
print("RESULT " + json.dumps(rows))
"""


def main(quick: bool = False) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ["src", env.get("PYTHONPATH", ""), "."] if p
    )
    out = subprocess.run(
        [sys.executable, "-c", _CHILD.format(quick=quick)],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded bench subprocess failed:\n{out.stdout}\n{out.stderr}"
        )
    rows = []
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            rows = json.loads(line[len("RESULT "):])
    base = {}
    for r in rows:
        key = r["matrix"]
        if r["shards"] == 1:
            base[key] = (r["spmv_s"], r["spmm_s"])
        b = base.get(key, (r["spmv_s"], r["spmm_s"]))
        csv_row(
            f"sharded/{key}/spmv/d{r['shards']}",
            r["spmv_s"] * 1e6,
            f"speedup={b[0] / max(r['spmv_s'], 1e-12):.2f},"
            f"imbalance={r['imbalance']:.3f}",
        )
        csv_row(
            f"sharded/{key}/spmm/d{r['shards']}",
            r["spmm_s"] * 1e6,
            f"speedup={b[1] / max(r['spmm_s'], 1e-12):.2f},"
            f"imbalance={r['imbalance']:.3f}",
        )


if __name__ == "__main__":
    main(quick=True)
