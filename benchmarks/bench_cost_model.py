"""Cost model: prediction quality and cold-start staging, predict vs measure.

ISSUE 8 acceptance instrumentation.  Builds a *measured* corpus by
autotuning a family of synthesized structures into a throwaway plan cache,
fits the cost model, then reports three things:

* leave-one-out prediction quality — top-1 backend agreement against the
  measured winner and MAE of the predicted log-runtime (the model is refit
  N times with one plan held out each time; closed-form ridge makes this
  cheap);
* cold-start staging latency for *new* in-distribution structures with
  ``mode="predict"`` (micro-benchmarks only on fallback) vs plain
  ``mode="measure"`` — the derived column records benchmark counts and the
  predicted/fallback split so the never-guess behaviour is checkable from
  BENCH_results.json;
* corpus-build cost, so the break-even point (structures tuned before
  prediction starts paying) is visible.

Agreement on real micro-benchmark timings is reported, not asserted —
noisy close calls are exactly what the margin gate routes back to
measurement (tests/test_cost_model.py asserts the >=80% bar on planted
log-linear corpora where ground truth is exact).
"""
from __future__ import annotations

import tempfile
import time
import zlib

import numpy as np

from repro.core import vbr as vbrlib
from repro.core import cost_model as cmlib
from repro.core.autotune import autotune, autotune_stats, reset_autotune_stats
from repro.core.cache import PlanCache
from repro.core.staging import clear_cache

from .common import csv_row


def _family(count: int, n: int):
    """One structure family (block-diagonal-ish VBR) swept over block
    count, so features vary along an in-distribution axis."""
    out = []
    for i in range(count):
        nb = 20 + 7 * i
        name = f"fam{n}x{n}b{nb}"
        out.append(
            (
                name,
                vbrlib.synthesize(
                    # crc32, not hash(): str hash is randomized per process,
                    # and BENCH_*.json rows must be comparable across runs
                    n, n, 20, 20, nb, 0.2, i % 2 == 0,
                    seed=zlib.crc32(name.encode()) % 2**31,
                ),
            )
        )
    return out


def main(quick: bool = True) -> None:
    n = 600 if quick else 2_000
    n_corpus = 10 if quick else 24
    n_held = 3 if quick else 8
    iters = 1 if quick else 3
    mats = _family(n_corpus + n_held, n)
    corpus_mats, held_mats = mats[:n_corpus], mats[n_corpus:]

    with tempfile.TemporaryDirectory() as root:
        cache = PlanCache(root)

        # -------- corpus build: measured ground truth ---------------- #
        clear_cache()
        reset_autotune_stats()
        t0 = time.perf_counter()
        for _, v in corpus_mats:
            autotune(v, "spmv", cache=cache, iters=iters)
        t_build = time.perf_counter() - t0
        csv_row(
            "cost_model/corpus_build",
            t_build / n_corpus * 1e6,
            f"plans={n_corpus};benchmarks={autotune_stats()['benchmarks']}",
        )

        # -------- leave-one-out prediction quality ------------------- #
        plans = cmlib.corpus(cache, plans_device(cache), "spmv")
        agree = total = 0
        errs = []
        for i, held in enumerate(plans):
            rest = plans[:i] + plans[i + 1 :]
            model = cmlib.fit(rest, held.device, "spmv")
            if model is None:
                continue
            preds = model.predict(cmlib.plan_features(held), held.timings)
            if not preds:
                continue
            total += 1
            if min(preds, key=preds.get) == min(held.timings, key=held.timings.get):
                agree += 1
            errs += [
                abs(np.log(max(preds[lbl], 1e-12)) - np.log(max(t, 1e-12)))
                for lbl, t in held.timings.items()
                if lbl in preds
            ]
        mae = float(np.mean(errs)) if errs else float("nan")
        csv_row(
            "cost_model/loo_quality",
            mae * 1e6,  # MAE in log-space, scaled like the other rows
            f"top1_agreement={agree / max(total, 1):.2f};n={total};mae_log={mae:.3f}",
        )

        # -------- cold-start staging: predict vs measure ------------- #
        clear_cache()
        reset_autotune_stats()
        cmlib.reset_cost_model_stats()
        t0 = time.perf_counter()
        for _, v in held_mats:
            autotune(v, "spmv", cache=cache, mode="predict", iters=iters)
        t_pred = time.perf_counter() - t0
        st, cst = autotune_stats(), cmlib.cost_model_stats()
        csv_row(
            "cost_model/predict_stage",
            t_pred / n_held * 1e6,
            f"benchmarks={st['benchmarks']};predicted={cst['plans_predicted']}"
            f";fallbacks={cst['predict_fallbacks']}",
        )

        with tempfile.TemporaryDirectory() as root2:
            clear_cache()
            reset_autotune_stats()
            t0 = time.perf_counter()
            for _, v in held_mats:
                autotune(v, "spmv", cache=PlanCache(root2), iters=iters)
            t_meas = time.perf_counter() - t0
        csv_row(
            "cost_model/measure_stage",
            t_meas / n_held * 1e6,
            f"benchmarks={autotune_stats()['benchmarks']}"
            f";predict_speedup={t_meas / max(t_pred, 1e-9):.1f}x",
        )
    clear_cache()


def plans_device(cache: PlanCache) -> str:
    """Device the corpus was measured on (single-device benchmark run)."""
    for p in cache.iter_plans(kind="spmv"):
        return p.device
    import jax

    return jax.default_backend()


if __name__ == "__main__":
    main()
