"""Shared benchmark utilities: timing, baselines, CSV output."""
from __future__ import annotations

import time
import zlib

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import vbr as vbrlib


def timeit(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


# every csv_row is also collected here; benchmarks/run.py dumps the list
# as BENCH_results.json (see benchmarks/README.md for the schema)
ROWS: list[dict] = []
CURRENT_SUITE: str | None = None


def csv_row(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    ROWS.append(
        {
            "suite": CURRENT_SUITE,
            "name": name,
            "us_per_call": float(us_per_call),
            "derived": str(derived),
        }
    )


# ----------------------------------------------------------------------- #
# Baseline strategy classes (see DESIGN.md §2: PSC/SpReg's CPU codebases
# don't run here; we implement their strategy class in JAX)
# ----------------------------------------------------------------------- #
def csr_spmv(v: vbrlib.VBR):
    """Gather-based unstructured CSR (the 'avoid every zero' class)."""
    d = v.to_dense()
    rows, cols = np.nonzero(d)
    vals = jnp.asarray(d[rows, cols])
    rows_j, cols_j = jnp.asarray(rows), jnp.asarray(cols)
    m = d.shape[0]

    @jax.jit
    def f(vals, x):
        return jnp.zeros(m, x.dtype).at[rows_j].add(vals * x[cols_j])

    return f, vals


def csr_spmm(v: vbrlib.VBR):
    d = v.to_dense()
    rows, cols = np.nonzero(d)
    vals = jnp.asarray(d[rows, cols])
    rows_j, cols_j = jnp.asarray(rows), jnp.asarray(cols)
    m = d.shape[0]

    @jax.jit
    def f(vals, x):
        return jnp.zeros((m, x.shape[1]), x.dtype).at[rows_j].add(
            vals[:, None] * x[cols_j]
        )

    return f, vals


def dense_spmv(v: vbrlib.VBR):
    d = jnp.asarray(v.to_dense())

    @jax.jit
    def f(d, x):
        return d @ x

    return f, d


def dense_spmm(v: vbrlib.VBR):
    return dense_spmv(v)


# paper-style matrix set, scaled by `scale` (1.0 = the paper's 10k x 10k)
def paper_matrices(scale: float = 0.2, zeros_pct: int = 20):
    n = int(10_000 * scale)
    cells = [
        (50, 50, 25, "u"),
        (50, 50, 500, "u"),
        (50, 100, 50, "u"),
        (100, 50, 250, "u"),
        (100, 100, 500, "u"),
        (50, 50, 25, "nu"),
        (50, 50, 500, "nu"),
        (100, 100, 500, "nu"),
    ]
    out = []
    for rs, cs, nb, kind in cells:
        v = vbrlib.synthesize(
            # crc32, not hash(): str hash is randomized per process, and
            # benchmark rows must be comparable across runs
            n, n, rs, cs, nb, zeros_pct / 100.0, kind == "u",
            seed=zlib.crc32(f"{rs},{cs},{nb},{kind}".encode()) % 2**31,
        )
        out.append((f"<{rs},{cs},{nb},{kind}>", v))
    return out
