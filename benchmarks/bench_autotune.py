"""Autotuner: cold-vs-warm staging latency and auto-vs-fixed throughput.

Demonstrates the persistent-cache contract (ISSUE 1 acceptance): the first
(`cold`) autotune of a structure stages and micro-benchmarks every
candidate; a second process staging the same pattern (`warm` — simulated by
wiping the in-memory caches but keeping the disk cache) loads the plan and
performs ZERO candidate benchmarks.  The derived column records the
benchmark count so the trajectory is checkable from BENCH_results.json.

Throughput rows compare the plan's measured winner against each fixed
backend on the same matrix.
"""
from __future__ import annotations

import tempfile
import time
import zlib

import numpy as np

from repro.core import vbr as vbrlib
from repro.core.autotune import (
    autotune,
    autotune_stage,
    autotune_stats,
    reset_autotune_stats,
)
from repro.core.cache import PlanCache
from repro.core.staging import StagingOptions, clear_cache, stage_spmv

from .common import csv_row, timeit


def _matrices(quick: bool):
    n = 1_000 if quick else 5_000
    cells = [
        ("<20,20,60,u>", 20, 20, 60, True),
        ("<20,20,60,nu>", 20, 20, 60, False),
        ("<50,50,200,nu>", 50, 50, 200, False),
    ]
    out = []
    for name, rs, cs, nb, uniform in cells:
        out.append(
            (
                name,
                vbrlib.synthesize(
                    # crc32, not hash(): str hash is randomized per process,
                    # and BENCH_*.json rows must be comparable across runs
                    n, n, rs, cs, nb, 0.2, uniform,
                    seed=zlib.crc32(name.encode()) % 2**31,
                ),
            )
        )
    return out


def main(quick: bool = True) -> None:
    iters = 1 if quick else 3
    with tempfile.TemporaryDirectory() as root:
        for name, v in _matrices(quick):
            x = np.random.default_rng(0).standard_normal(v.shape[1]).astype(
                np.float32
            )

            # -------- cold: full candidate search -------------------- #
            clear_cache()
            reset_autotune_stats()
            t0 = time.perf_counter()
            plan = autotune(v, "spmv", cache=PlanCache(root), iters=iters)
            t_cold = time.perf_counter() - t0
            n_cold = autotune_stats()["benchmarks"]
            csv_row(
                f"autotune/{name}/cold_stage",
                t_cold * 1e6,
                f"benchmarks={n_cold};winner={plan.options.backend}",
            )

            # -------- warm: fresh process, same disk cache ----------- #
            clear_cache()
            reset_autotune_stats()
            t0 = time.perf_counter()
            kern = autotune_stage(v, "spmv", cache=PlanCache(root))
            t_warm = time.perf_counter() - t0
            n_warm = autotune_stats()["benchmarks"]
            assert n_warm == 0, "warm cache must not micro-benchmark"
            csv_row(
                f"autotune/{name}/warm_stage",
                t_warm * 1e6,
                f"benchmarks={n_warm};speedup={t_cold / max(t_warm, 1e-9):.1f}x",
            )

            # -------- throughput: measured winner vs fixed backends -- #
            t_auto = timeit(kern, v.val, x)
            csv_row(f"autotune/{name}/spmv_auto", t_auto * 1e6, plan.options.backend)
            for backend in ("grouped", "bucketed"):
                k = stage_spmv(v, StagingOptions(backend=backend))
                t_fix = timeit(k, v.val, x)
                csv_row(
                    f"autotune/{name}/spmv_{backend}",
                    t_fix * 1e6,
                    f"vs_auto={t_fix / max(t_auto, 1e-9):.2f}x",
                )
    clear_cache()


if __name__ == "__main__":
    main()
