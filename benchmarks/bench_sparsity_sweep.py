"""Figs 7/10 analog: speedup vs intra-block sparsity.

The paper's claim: SABLE wins up to ~75% zeros in the blocks, because
computing over zeros beats gathering around them; beyond that the wasted
work dominates.  We sweep block sparsity and report staged-vs-CSR speedup
(the crossover is the 'how many zeros can regularity tolerate' curve).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import vbr as vbrlib
from repro.core.staging import StagingOptions, stage_spmv

from .common import csr_spmv, csv_row, timeit


def run(n: int = 2000, iters: int = 10) -> None:
    for sparsity in (0.0, 0.25, 0.5, 0.75, 0.9, 0.95):
        v = vbrlib.synthesize(n, n, 50, 50, 100, sparsity, True, seed=7)
        x = jnp.asarray(
            np.random.default_rng(1).standard_normal(n), jnp.float32
        )
        val = jnp.asarray(v.val)
        k = stage_spmv(v, StagingOptions(backend="grouped"))
        t_sable = timeit(k, val, x, iters=iters)
        kc, cvals = csr_spmv(v)
        t_csr = timeit(kc, cvals, x, iters=iters)
        csv_row(
            f"sparsity_sweep/z{int(sparsity*100)}",
            t_sable * 1e6,
            f"{t_csr/t_sable:.2f}x_vs_csr",
        )


def main(quick: bool = False):
    run(n=1000 if quick else 2000, iters=5 if quick else 10)


if __name__ == "__main__":
    main()
