"""Benchmark aggregator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes the same rows (plus run
metadata) to ``BENCH_results.json`` — schema in benchmarks/README.md.
``--full`` uses larger (closer to paper-scale) matrices; the default
'quick' sizes keep the whole suite a few minutes on one CPU core.

  PYTHONPATH=src python -m benchmarks.run [--full | --smoke]
                                          [--only spmv,spmm,...]
                                          [--json PATH | --no-json]

``--smoke`` is the CI mode: quick sizes, a small representative suite
subset (one kernel suite + the sharded scaling sweep), same JSON schema.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

from . import (
    bench_autotune,
    bench_codegen_variants,
    bench_cost_model,
    bench_inspection,
    bench_mesh2d,
    bench_moe,
    bench_reblock,
    bench_scaling,
    bench_serving,
    bench_sharded,
    bench_sparsity_sweep,
    bench_spmm,
    bench_spmv,
    common,
    roofline,
)

SUITES = {
    "spmv": bench_spmv.main,  # Table I
    "spmm": bench_spmm.main,  # Table III
    "sparsity": bench_sparsity_sweep.main,  # Figs 7/10
    "codegen": bench_codegen_variants.main,  # Figs 8/11
    "inspection": bench_inspection.main,  # Tables II/IV
    "scaling": bench_scaling.main,  # Figs 6/9
    "roofline": roofline.main,  # §Roofline (from dry-run artifacts)
    "autotune": bench_autotune.main,  # ISSUE 1: cold/warm plan cache
    "sharded": bench_sharded.main,  # ISSUE 3: 1/2/4/8-device shard_map
    "mesh2d": bench_mesh2d.main,  # ISSUE 5: (shards x model) factorizations
    "serving": bench_serving.main,  # ISSUE 6: continuous-batching traffic
    "moe": bench_moe.main,  # ISSUE 7: dense-capacity vs dropless FFN
    "cost_model": bench_cost_model.main,  # ISSUE 8: predict vs measure
    "reblock": bench_reblock.main,  # ISSUE 9: reblocking + DIA-hybrid
}

SMOKE_SUITES = (
    "spmv", "sharded", "mesh2d", "serving", "moe", "cost_model", "reblock",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: quick sizes, representative suite subset")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default="BENCH_results.json")
    ap.add_argument("--no-json", action="store_true")
    args, _ = ap.parse_known_args()
    if args.smoke and args.full:
        ap.error("--smoke and --full are mutually exclusive")
    default = set(SMOKE_SUITES) if args.smoke else set(SUITES)
    only = set(args.only.split(",")) if args.only else default
    unknown = only - set(SUITES)
    if unknown:
        ap.error(
            f"unknown suite(s) {sorted(unknown)}; known: {sorted(SUITES)}"
        )
    print("name,us_per_call,derived")
    failed = []
    for name, fn in SUITES.items():
        if name not in only:
            continue
        common.CURRENT_SUITE = name
        try:
            fn(quick=not args.full)
        except Exception as e:  # keep the suite going; report at the end
            traceback.print_exc()
            failed.append((name, e))
        finally:
            common.CURRENT_SUITE = None
    if not args.no_json and common.ROWS:
        import jax

        doc = {
            "version": 1,
            "jax_backend": jax.default_backend(),
            "mode": "full" if args.full else "quick",
            "failed_suites": [name for name, _ in failed],
            "rows": common.ROWS,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {len(common.ROWS)} rows to {args.json}", file=sys.stderr)
    if failed:
        for name, e in failed:
            print(f"FAILED suite {name}: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
