"""Benchmark aggregator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` uses larger (closer to
paper-scale) matrices; the default 'quick' sizes keep the whole suite a few
minutes on one CPU core.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only spmv,spmm,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (
    bench_codegen_variants,
    bench_inspection,
    bench_scaling,
    bench_sparsity_sweep,
    bench_spmm,
    bench_spmv,
    roofline,
)

SUITES = {
    "spmv": bench_spmv.main,  # Table I
    "spmm": bench_spmm.main,  # Table III
    "sparsity": bench_sparsity_sweep.main,  # Figs 7/10
    "codegen": bench_codegen_variants.main,  # Figs 8/11
    "inspection": bench_inspection.main,  # Tables II/IV
    "scaling": bench_scaling.main,  # Figs 6/9
    "roofline": roofline.main,  # §Roofline (from dry-run artifacts)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name, fn in SUITES.items():
        if name not in only:
            continue
        try:
            fn(quick=not args.full)
        except Exception as e:  # keep the suite going; report at the end
            traceback.print_exc()
            failed.append((name, e))
    if failed:
        for name, e in failed:
            print(f"FAILED suite {name}: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
