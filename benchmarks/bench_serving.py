"""Synthetic serving traffic through the continuous-batching scheduler.

Replays Poisson and bursty arrival processes with mixed prompt/generation
lengths against ``ContinuousBatchingScheduler`` on the wall clock and
reports request latency (p50/p99), time-to-first-token, and decode
throughput — plus the warm-restart row: a restarted engine + scheduler
over an already-populated plan cache must stage ZERO new plans.

The measurement runs in a subprocess with ``JAX_PLATFORMS=cpu`` pinned
(leaving the platform unset makes jax probe for accelerator plugins,
which idles for minutes on images with the TPU toolchain) and a throwaway
``REPRO_CACHE_DIR`` so the warm-restart measurement starts from a
genuinely cold plan cache.

Standalone CI entry point::

    PYTHONPATH=src python -m benchmarks.bench_serving --smoke
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from . import common
from .common import csv_row

_CHILD = """
import time
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config, llama3_8b
from repro.core.cache import PlanCache
from repro.models import init_params
from repro.serve.engine import ServeEngine
from repro.sparse import random_pattern

quick = {quick}
cfg = get_config("llama3.2-3b", reduced=True)
params = init_params(cfg, jax.random.PRNGKey(0))
eng = ServeEngine(cfg, params, max_len=32)


def workload(kind, n, seed):
    \"\"\"(arrival_offset_s, prompt, max_new) triples: Poisson (exponential
    inter-arrival) or bursty (groups of 4 back-to-back, long gaps).\"\"\"
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for i in range(n):
        if kind == "poisson":
            t += float(rng.exponential(0.03))
        elif i % 4 == 0 and i > 0:
            t += 0.25  # burst gap
        P = int(rng.integers(4, 17))
        G = int(rng.integers(4, 17))
        prompt = rng.integers(0, cfg.vocab_size, size=(P,)).astype(np.int32)
        out.append((t, prompt, G))
    return out


def replay(kind, n, seed):
    \"\"\"Drive the scheduler against the wall clock: submit each request
    when its arrival time passes, step whenever lanes/queue have work.\"\"\"
    sched = eng.make_scheduler(page_size=8, max_batch=4)
    arrivals = workload(kind, n, seed)
    t0 = time.perf_counter()
    i = 0
    while i < len(arrivals) or sched.pending():
        now = time.perf_counter() - t0
        while i < len(arrivals) and arrivals[i][0] <= now:
            t, prompt, G = arrivals[i]
            sched.submit(prompt, G, rid=f"{{kind}}{{i}}", arrival=t0 + t)
            i += 1
        if sched.pending():
            sched.step()
        elif i < len(arrivals):
            time.sleep(min(arrivals[i][0] - now, 0.01))
    makespan = time.perf_counter() - t0
    lat, ttft = [], []
    for req in sched.requests.values():
        lat.append(req.metrics["finished_at"] - req.arrival)
        ttft.append(req.metrics["first_token_at"] - req.arrival)
    return {{
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "p50_ttft_s": float(np.percentile(ttft, 50)),
        "tokens_per_s": sched.stats["decode_tokens"] / max(makespan, 1e-9),
        "makespan_s": makespan,
        "steps": sched.stats["steps"],
        "evictions": sched.stats["evictions"],
        "finished": sched.stats["finished"],
    }}


def shared_prefix_replay(n, seed, *, sharing):
    \"\"\"Poisson arrivals where every prompt starts with the same 24-token
    system prompt — the page-sharing showcase.  Run once with sharing +
    chunked prefill ON and once OFF to measure the TTFT and page-footprint
    win; decode output is token-identical either way.\"\"\"
    rng = np.random.default_rng(seed)
    system = rng.integers(0, cfg.vocab_size, size=(24,)).astype(np.int32)
    sched = eng.make_scheduler(
        page_size=8, max_batch=4, max_len=40,
        prefix_sharing=sharing, chunked_prefill=sharing,
    )
    arrivals = []
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(0.03))
        P = int(rng.integers(2, 7))
        G = int(rng.integers(4, 9))
        suffix = rng.integers(0, cfg.vocab_size, size=(P,)).astype(np.int32)
        arrivals.append((t, np.concatenate([system, suffix]), G))
    t0 = time.perf_counter()
    i = 0
    while i < len(arrivals) or sched.pending():
        now = time.perf_counter() - t0
        while i < len(arrivals) and arrivals[i][0] <= now:
            at, prompt, G = arrivals[i]
            sched.submit(prompt, G, rid=f"sp{{i}}", arrival=t0 + at)
            i += 1
        if sched.pending():
            sched.step()
        elif i < len(arrivals):
            time.sleep(min(arrivals[i][0] - now, 0.01))
    makespan = time.perf_counter() - t0
    ttft = [
        req.metrics["first_token_at"] - req.arrival
        for req in sched.requests.values()
    ]
    return {{
        "p50_ttft_s": float(np.percentile(ttft, 50)),
        "p99_ttft_s": float(np.percentile(ttft, 99)),
        "makespan_s": makespan,
        "pages_allocated_total": sched.kv.allocator.total_allocated,
        "prefill_tokens": sched.stats["prefill_tokens"],
        "prefix_hits": sched.stats["prefix_hits"],
        "pages_shared": sched.stats["pages_shared"],
        "cow_copies": sched.stats["cow_copies"],
        "tokens": {{
            rid: np.asarray(req.tokens).tolist()
            for rid, req in sched.requests.items()
        }},
    }}


n = 12 if quick else 48
result = {{
    "poisson": replay("poisson", n, seed=1),
    "bursty": replay("bursty", n, seed=2),
    "shared_prefix_on": shared_prefix_replay(n, seed=3, sharing=True),
    "shared_prefix_off": shared_prefix_replay(n, seed=3, sharing=False),
}}

# ---- warm restart: engine warmup + scheduler admission stage zero plans
sable_cfg = llama3_8b.reduced_sable()
sable_params = init_params(sable_cfg, jax.random.PRNGKey(0))
t0 = time.perf_counter()
eng_cold = ServeEngine(sable_cfg, sable_params, max_len=16)
cold_s = time.perf_counter() - t0
t0 = time.perf_counter()
eng_warm = ServeEngine(sable_cfg, sable_params, max_len=16)
warm_s = time.perf_counter() - t0
store = PlanCache()
pat = (random_pattern(64, 64, 16, 16, 0.4, seed=5),)
rng = np.random.default_rng(9)
def serve_pat():
    sched = eng.make_scheduler(page_size=8, max_batch=2, plan_cache=store)
    for i in range(2):
        sched.submit(
            rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32),
            4, patterns=pat, rid=f"w{{i}}{{time.perf_counter()}}",
        )
    sched.run()
    return sched.stats["plans_staged"]
result["warm_restart"] = {{
    "engine_cold_staged": eng_cold.warmup_stats["plans_staged"],
    "engine_warm_staged": eng_warm.warmup_stats["plans_staged"],
    "engine_warm_start": eng_warm.warmup_stats["warm_start"],
    "engine_cold_s": cold_s,
    "engine_warm_s": warm_s,
    "sched_cold_staged": serve_pat(),
    "sched_warm_staged": serve_pat(),
}}
print("RESULT " + json.dumps(result))
"""


def main(quick: bool = False) -> None:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["REPRO_CACHE_DIR"] = tempfile.mkdtemp(prefix="bench-serving-")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ["src", env.get("PYTHONPATH", ""), "."] if p
    )
    out = subprocess.run(
        [sys.executable, "-c", "import json\n" + _CHILD.format(quick=quick)],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    if out.returncode != 0:
        raise RuntimeError(
            f"serving bench subprocess failed:\n{out.stdout}\n{out.stderr}"
        )
    result = None
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            result = json.loads(line[len("RESULT "):])
    assert result is not None, out.stdout
    for kind in ("poisson", "bursty"):
        r = result[kind]
        csv_row(
            f"serving/{kind}/latency",
            r["p50_latency_s"] * 1e6,
            f"p99_us={r['p99_latency_s'] * 1e6:.0f},"
            f"ttft_p50_us={r['p50_ttft_s'] * 1e6:.0f},"
            f"tok_per_s={r['tokens_per_s']:.1f},"
            f"evictions={r['evictions']},finished={r['finished']}",
        )
    on, off = result["shared_prefix_on"], result["shared_prefix_off"]
    # the sharing win must be real: hits registered, strictly fewer pages
    # ever allocated, fewer prefill tokens computed — and decode output
    # identical to the non-sharing run
    assert on["prefix_hits"] > 0, on
    assert on["pages_allocated_total"] < off["pages_allocated_total"], (on, off)
    assert on["prefill_tokens"] < off["prefill_tokens"], (on, off)
    assert on["tokens"] == off["tokens"], "sharing changed decode output"
    for label, r in (("on", on), ("off", off)):
        csv_row(
            f"serving/shared_prefix/{label}",
            r["p50_ttft_s"] * 1e6,
            f"ttft_p99_us={r['p99_ttft_s'] * 1e6:.0f},"
            f"pages_total={r['pages_allocated_total']},"
            f"prefill_tokens={r['prefill_tokens']},"
            f"prefix_hits={r['prefix_hits']},"
            f"pages_shared={r['pages_shared']},"
            f"cow_copies={r['cow_copies']}",
        )
    w = result["warm_restart"]
    assert w["engine_warm_staged"] == 0 and w["engine_warm_start"], w
    assert w["sched_warm_staged"] == 0, w
    csv_row(
        "serving/warm_restart/engine",
        w["engine_warm_s"] * 1e6,
        f"cold_us={w['engine_cold_s'] * 1e6:.0f},"
        f"cold_staged={w['engine_cold_staged']},warm_staged=0",
    )
    csv_row(
        "serving/warm_restart/scheduler",
        0.0,
        f"cold_staged={w['sched_cold_staged']},warm_staged=0",
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small workload, write BENCH_results.json")
    ap.add_argument("--json", default="BENCH_results.json")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    common.CURRENT_SUITE = "serving"
    print("name,us_per_call,derived")
    main(quick=args.smoke)
    common.CURRENT_SUITE = None
    if not args.no_json:
        doc = {
            "version": 1,
            "mode": "smoke" if args.smoke else "quick",
            "failed_suites": [],
            "rows": common.ROWS,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {len(common.ROWS)} rows to {args.json}", file=sys.stderr)
