"""Tables II/IV analog: inspection (staging + Stage-2 compile) time.

Paper: SABLE's inspection = codegen + gcc compile; compile-once/run-many
amortizes it.  Here Stage-2 is XLA; we report Stage-0 (block iteration +
pattern matching) and Stage-2 (AOT compile) separately, plus the cache-hit
cost for a second matrix with the same pattern (~0: the paper's reuse
contract).  ``derived`` = compile fraction of inspection.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import vbr as vbrlib
from repro.core.staging import StagedKernel, StagingOptions, clear_cache, stage_spmv

from .common import csv_row


def run(scale: float = 0.2) -> None:
    n = int(10_000 * scale)
    for rs, cs, nb, zp, kind in [
        (50, 50, 25, 20, "u"),
        (50, 50, 500, 20, "u"),
        (50, 50, 500, 50, "u"),
        (50, 50, 500, 75, "u"),
        (100, 100, 500, 75, "u"),
        (50, 50, 500, 20, "nu"),
    ]:
        v = vbrlib.synthesize(n, n, rs, cs, nb, zp / 100, kind == "u",
                              seed=nb + zp)
        clear_cache()
        k = StagedKernel("spmv", v, StagingOptions(backend="grouped"))
        k.compile(
            jax.ShapeDtypeStruct(v.val.shape, jnp.float32),
            jax.ShapeDtypeStruct((v.shape[1],), jnp.float32),
        )
        insp_ms = k.inspection_time * 1e3
        frac = k.compile_time / max(k.inspection_time, 1e-12)
        csv_row(f"inspection/<{rs},{cs},{nb},{zp},{kind}>", insp_ms * 1e3,
                f"compile_frac={frac:.2f}")
        # compile-once / run-many: same pattern, new values
        v2 = vbrlib.VBR(**{**v.__dict__})
        v2.val = v.val * 2.0
        t0 = time.perf_counter()
        k2 = stage_spmv(v2, StagingOptions(backend="grouped"))
        hit_ms = (time.perf_counter() - t0) * 1e3
        csv_row(f"inspection/<{rs},{cs},{nb},{zp},{kind}>/cache-hit",
                hit_ms * 1e3, f"reuse={'hit' if k2 is k else 'miss'}")


def main(quick: bool = False):
    run(scale=0.1 if quick else 0.2)


if __name__ == "__main__":
    main()
