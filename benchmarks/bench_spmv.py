"""Table I analog: SpMV execution time, SABLE vs baseline strategies.

Paper: SABLE vs PSC on 10k x 10k VBR matrices at 0/20/50% block zeros.
Here: staged backends (unrolled = paper-faithful per-block codegen,
grouped = shape-class codegen) vs the gather-based CSR class and dense.
``derived`` column = speedup over CSR (the zero-avoiding strategy).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.staging import StagingOptions, stage_spmv

from .common import csr_spmv, csv_row, dense_spmv, paper_matrices, timeit


def run(scale: float = 0.2, zeros_pcts=(0, 20, 50), iters: int = 10) -> None:
    for zp in zeros_pcts:
        for name, v in paper_matrices(scale, zp):
            x = jnp.asarray(
                np.random.default_rng(0).standard_normal(v.shape[1]), jnp.float32
            )
            val = jnp.asarray(v.val)
            kc, cvals = csr_spmv(v)
            t_csr = timeit(kc, cvals, x, iters=iters)
            kd, dmat = dense_spmv(v)
            t_dense = timeit(kd, dmat, x, iters=iters)
            kg = stage_spmv(v, StagingOptions(backend="grouped"))
            t_grouped = timeit(kg, val, x, iters=iters)
            ku = stage_spmv(v, StagingOptions(backend="unrolled"))
            t_unrolled = timeit(ku, val, x, iters=iters)
            csv_row(f"spmv/{name}/z{zp}/sable-grouped", t_grouped * 1e6,
                    f"{t_csr/t_grouped:.2f}x_vs_csr")
            csv_row(f"spmv/{name}/z{zp}/sable-unrolled", t_unrolled * 1e6,
                    f"{t_csr/t_unrolled:.2f}x_vs_csr")
            csv_row(f"spmv/{name}/z{zp}/csr", t_csr * 1e6, "1.00x_vs_csr")
            csv_row(f"spmv/{name}/z{zp}/dense", t_dense * 1e6,
                    f"{t_csr/t_dense:.2f}x_vs_csr")


def main(quick: bool = False):
    run(scale=0.1 if quick else 0.2, iters=5 if quick else 10,
        zeros_pcts=(20,) if quick else (0, 20, 50))


if __name__ == "__main__":
    main()
