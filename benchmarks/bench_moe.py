"""Dropless MoE routed FFN: dense capacity-buffer einsums vs the
block-sparse sdd/dsd formulation (ISSUE 7).

Both paths consume the SAME (G, E, C, d) capacity buffer; the dense path
multiplies every capacity slot (occupied or not) through its expert's FFN,
the dropless path touches only the occupied capacity blocks
(``models.moe._dropless_ffn``).  The occupancy sweep pins the story: the
sparse path computes ``flops_fraction`` of the dense FLOPs (the static
nnz bound over the full block grid — occupancy plus up to one partial
block per expert), so its win should track 1/flops_fraction; the
``derived`` column reports speedup next to that fraction
(``proportionality = speedup * flops_fraction``, ~1 when the win is
FLOPs-proportional).  The occupancy-0.25 rows are the acceptance case:
75% of the capacity blocks empty.

Standalone CI entry point::

    PYTHONPATH=src python -m benchmarks.bench_moe --smoke
"""
from __future__ import annotations

import json
import sys

import numpy as np
import jax
import jax.numpy as jnp

from . import common
from .common import csv_row, timeit


def _cfg(E: int, d: int, f: int, bm: int):
    from repro.models.config import (
        LayerSpec,
        ModelConfig,
        MoEConfig,
        uniform_groups,
    )

    moe = MoEConfig(num_experts=E, top_k=1, d_ff=f, dropless=True,
                    dropless_block=bm)
    return ModelConfig(
        name="bench-moe", family="moe", d_model=d, n_heads=2, n_kv_heads=2,
        head_dim=8, d_ff=f, vocab_size=64,
        groups=uniform_groups(1, LayerSpec(ffn="moe")),
        ffn_type="relu2", moe=moe,
    )


def _buffer(rng, G, E, C, d, occupancy):
    """(buf, counts): each expert's first ``occupancy * C`` capacity slots
    hold tokens, the rest are zero — the buffer moe_apply's dispatch
    produces at per-expert load ``occupancy``."""
    n = int(round(C * occupancy))
    buf = np.zeros((G, E, C, d), np.float32)
    buf[:, :, :n] = rng.standard_normal((G, E, n, d)).astype(np.float32)
    counts = np.full((G, E), n, np.int32)
    return jnp.asarray(buf), jnp.asarray(counts)


def main(quick: bool = True) -> None:
    from repro.models.layers import _act
    from repro.models.moe import _dropless_ffn

    G, E, C, d, f, bm = (
        (1, 8, 512, 256, 256, 64) if quick else (2, 16, 1024, 256, 256, 64)
    )
    cfg = _cfg(E, d, f, bm)
    rng = np.random.default_rng(0)
    w1 = jnp.asarray(rng.standard_normal((E, d, f)).astype(np.float32))
    w2 = jnp.asarray(rng.standard_normal((E, f, d)).astype(np.float32))
    p = {"w1": w1, "w2": w2}

    @jax.jit
    def dense_ffn(buf):
        h = _act(cfg, jnp.einsum("gecd,edf->gecf", buf, w1))
        return jnp.einsum("gecf,efd->gecd", h, w2)

    for occupancy in (0.25, 0.5, 1.0):
        buf, counts = _buffer(rng, G, E, C, d, occupancy)
        # per-group assignment total sizes the static nnz bound (in
        # moe_apply this is Tg * top_k); tight bound = FLOPs-proportional
        total = int(np.asarray(counts)[0].sum())
        sparse_ffn = jax.jit(
            lambda buf, counts, _t=total: _dropless_ffn(p, buf, counts, _t, cfg)
        )
        ref = dense_ffn(buf)
        out = sparse_ffn(buf, counts)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4
        )
        td = timeit(dense_ffn, buf, warmup=2, iters=5)
        ts = timeit(sparse_ffn, buf, counts, warmup=2, iters=5)
        # blocks the sparse path actually computes / blocks in the grid
        nnz = min(E * (C // bm), -(-total // bm) + E)
        frac = nnz / (E * (C // bm))
        tag = f"G{G}xE{E}xC{C}xd{d}"
        csv_row(
            f"moe_ffn/dense/{tag}/occ{occupancy}",
            td * 1e6,
            "flops_fraction=1.00",
        )
        csv_row(
            f"moe_ffn/dropless/{tag}/occ{occupancy}",
            ts * 1e6,
            f"speedup={td / ts:.2f},flops_fraction={frac:.3f},"
            f"proportionality={(td / ts) * frac:.2f}",
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: quick sizes, write BENCH_results.json")
    ap.add_argument("--json", default="BENCH_results.json")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    common.CURRENT_SUITE = "moe"
    print("name,us_per_call,derived")
    main(quick=args.smoke)
    common.CURRENT_SUITE = None
    if not args.no_json:
        doc = {
            "version": 1,
            "mode": "smoke" if args.smoke else "quick",
            "failed_suites": [],
            "rows": common.ROWS,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {len(common.ROWS)} rows to {args.json}", file=sys.stderr)
