"""Roofline report: aggregate dry-run JSONs into the §Roofline table.

Per (arch x shape x mesh): the three terms (seconds), dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS (useful-compute ratio), and the roofline fraction
  RF = t_compute / max(terms)
i.e. the fraction of the compute roofline attainable with perfect overlap —
RF = 1.0 means compute-bound at peak; the hillclimb drives max(terms) down
toward t_compute.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
      [--md experiments/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_reports(d: str) -> list:
    out = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.1f}"


def row_of(r: dict) -> dict:
    rf = r["roofline"]
    bound = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
    return {
        "arch": r["arch"],
        "shape": r["shape"],
        "mesh": r["mesh"],
        "compute_ms": rf["t_compute_s"] * 1e3,
        "memory_ms": rf["t_memory_s"] * 1e3,
        "collective_ms": rf["t_collective_s"] * 1e3,
        "dominant": rf["dominant"],
        "bound_ms": bound * 1e3,
        "roofline_fraction": rf["t_compute_s"] / bound if bound else 0.0,
        "useful_ratio": r.get("useful_flops_ratio", 0.0),
        "args_gb": r.get("memory", {}).get("argument_size_in_bytes", 0) / 1e9,
        "temp_gb": r.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
    }


HEADER = (
    "| arch | shape | mesh | compute ms | memory ms | collective ms | "
    "dominant | RF | model/HLO flops | args GB/dev | temp GB/dev |"
)
SEP = "|" + "---|" * 11


def to_markdown(reports: list) -> str:
    lines = [HEADER, SEP]
    ok = [r for r in reports if r.get("status") == "ok"]
    skipped = [r for r in reports if r.get("status") == "skipped"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        w = row_of(r)
        lines.append(
            f"| {w['arch']} | {w['shape']} | {w['mesh']} "
            f"| {w['compute_ms']:.2f} | {w['memory_ms']:.2f} "
            f"| {w['collective_ms']:.2f} | {w['dominant']} "
            f"| {w['roofline_fraction']:.3f} | {w['useful_ratio']:.2f} "
            f"| {w['args_gb']:.2f} | {w['temp_gb']:.2f} |"
        )
    if skipped:
        lines.append("")
        lines.append("Skipped cells (documented):")
        for r in sorted(skipped, key=lambda r: (r["arch"], r["shape"])):
            lines.append(f"- {r['arch']} x {r['shape']} x {r['mesh']}: "
                         f"{r['reason']}")
    return "\n".join(lines)


def main(quick: bool = False, directory: str = "experiments/dryrun",
         md_out: str = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=directory)
    ap.add_argument("--md", default=md_out)
    args, _ = ap.parse_known_args()
    reports = load_reports(args.dir)
    if not reports:
        print(f"roofline/no-reports,0.0,dir={args.dir}")
        return
    ok = [r for r in reports if r.get("status") == "ok"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        w = row_of(r)
        print(
            f"roofline/{w['arch']}/{w['shape']}/{w['mesh']},"
            f"{w['bound_ms']*1e3:.1f},"
            f"RF={w['roofline_fraction']:.3f}:dom={w['dominant']}"
        )
    md = to_markdown(reports)
    if args.md:
        os.makedirs(os.path.dirname(args.md) or ".", exist_ok=True)
        with open(args.md, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()
