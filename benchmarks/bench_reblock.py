"""Reblocking + DIA-hybrid: tuned format choice vs the as-given blocking.

ISSUE 9 acceptance: with ``include_reblock=True`` the autotuner enumerates
structure-derived candidates (Ahrens-Boman DP reblockings, the MXU-aligned
1-bounded blocking, the Fukaya DIA-hybrid split) next to the fixed-layout
backends.  Per pattern this suite reports

  * ``cold_stage``    full extended search (detection + DP + benchmarks),
  * ``spmv_tuned``    throughput of the extended-space winner,
  * ``spmv_asgiven``  throughput of the base-space winner on the SAME
                      matrix (the as-given blocking; ratio in derived),
  * ``warm_stage``    restage from the persisted plan — asserted to run
                      ZERO micro-benchmarks and ZERO partition DPs.

``banded`` and ``arrow`` store their structure under fine structure-blind
splits — the showcase the acceptance criteria name (the DP repairs the
blocking; on the band the DIA split also competes).  ``banded_wellblocked``
and ``random`` are controls: the extended search must not lose to as-given
there (worst case it picks the same backend and pays only the one-off
cold inspection).
"""
from __future__ import annotations

import tempfile
import time
import zlib

import numpy as np

from repro.core import vbr as vbrlib
from repro.core.autotune import (
    autotune,
    autotune_stage,
    autotune_stats,
    reset_autotune_stats,
)
from repro.core.cache import PlanCache
from repro.core.reblock import reblock_stats, reset_reblock_stats
from repro.core.staging import clear_cache

from .common import csv_row, timeit


def _seed(name: str) -> int:
    # crc32, not hash(): str hash is randomized per process, and
    # BENCH_*.json rows must be comparable across runs
    return zlib.crc32(name.encode()) % 2**31


def _band(n: int, bw: int, rng) -> np.ndarray:
    dense = np.zeros((n, n), np.float32)
    for i in range(n):
        lo, hi = max(0, i - bw), min(n, i + bw + 1)
        dense[i, lo:hi] = rng.standard_normal(hi - lo)
    return dense


def _matrices(quick: bool):
    n = 768 if quick else 3_072
    bw = 12 if quick else 24
    fine = sorted({0, n, *range(0, n, 4)})  # as-given blocking that
    out = []                                # ignores the structure

    # banded (the acceptance pattern): a narrow band stored under fine
    # splits that ignore it — the DP repairs the blocking / DIA splits it
    rng = np.random.default_rng(_seed("banded"))
    out.append(("banded", vbrlib.from_dense(_band(n, bw, rng), fine, fine)))

    # arrow (the acceptance pattern): dense hub + block diagonal, again
    # stored under structure-blind fine splits
    rng = np.random.default_rng(_seed("arrow"))
    hub = n // 8
    coarse = sorted({0, n, hub, *range(hub, n, n // 8)})
    dense = np.zeros((n, n), np.float32)
    dense[:hub, :] = rng.standard_normal((hub, n))
    dense[:, :hub] = rng.standard_normal((n, hub))
    for a, b in zip(coarse[:-1], coarse[1:]):
        dense[a:b, a:b] = rng.standard_normal((b - a, b - a))
    out.append(("arrow", vbrlib.from_dense(dense, fine, fine)))

    # partially diagonal: a few dense diagonals + random noise entries —
    # the DIA-hybrid's home turf (diagonals scatter-free, noise staged)
    rng = np.random.default_rng(_seed("partially_diagonal"))
    dense = np.zeros((n, n), np.float32)
    for off in (0, -1, 1, n // 16):
        idx = np.arange(max(0, -off), min(n, n - off))
        dense[idx, idx + off] = rng.standard_normal(len(idx))
    nz = rng.integers(0, n, (n // 2, 2))
    dense[nz[:, 0], nz[:, 1]] = rng.standard_normal(len(nz))
    splits = sorted({0, n, *range(0, n, 8)})
    out.append(
        ("partially_diagonal", vbrlib.from_dense(dense, splits, splits))
    )

    # banded, well blocked (control): splits already follow the band, so
    # the extended search should keep the as-given layout
    rng = np.random.default_rng(_seed("banded_wellblocked"))
    splits = sorted({0, n, *range(0, n, 2 * bw)})
    out.append(
        ("banded_wellblocked",
         vbrlib.from_dense(_band(n, bw, rng), splits, splits))
    )

    # random block (control): the generic VBR regime — no structure to
    # exploit, detection must route it through the base candidates
    out.append(
        ("random",
         vbrlib.synthesize(n, n, 32, 32, 3 * (n // 32), 0.2, False,
                           seed=_seed("random")))
    )
    return out


def _label(plan) -> str:
    if plan.reblock is not None:
        return f"reblock[{plan.reblock['strategy']}]+{plan.options.backend}"
    return plan.options.backend


def main(quick: bool = True) -> None:
    iters = 3 if quick else 10  # winner selection must beat CPU noise
    for name, v in _matrices(quick):
        x = np.random.default_rng(0).standard_normal(v.shape[1]).astype(
            np.float32
        )
        with tempfile.TemporaryDirectory() as root:
            # ---- cold: extended search (detection + DP + measure) ---- #
            clear_cache()
            reset_autotune_stats()
            reset_reblock_stats()
            t0 = time.perf_counter()
            plan = autotune(
                v, "spmv", cache=PlanCache(root), include_reblock=True,
                iters=iters,
            )
            t_cold = time.perf_counter() - t0
            stats = autotune_stats()
            csv_row(
                f"reblock/{name}/cold_stage",
                t_cold * 1e6,
                f"benchmarks={stats['benchmarks']};winner={_label(plan)};"
                f"class={plan.meta.get('structure_class')}",
            )

            # ---- throughput: extended winner vs as-given winner ------ #
            # base first, then tuned, generous warmup: when both searches
            # pick the same backend the two rows must come out ~equal
            kern = autotune_stage(
                v, "spmv", cache=PlanCache(root), include_reblock=True
            )
            plan_base = autotune(v, "spmv", cache=PlanCache(root), iters=iters)
            kern_base = autotune_stage(v, "spmv", cache=PlanCache(root))
            t_base = timeit(kern_base, v.val, x, warmup=5, iters=30)
            t_tuned = timeit(kern, v.val, x, warmup=5, iters=30)
            csv_row(
                f"reblock/{name}/spmv_tuned", t_tuned * 1e6, _label(plan)
            )
            csv_row(
                f"reblock/{name}/spmv_asgiven",
                t_base * 1e6,
                f"{plan_base.options.backend};"
                f"tuned_speedup={t_base / max(t_tuned, 1e-9):.2f}x",
            )

            # ---- warm: plan + structures off disk, zero re-derivation - #
            clear_cache()
            reset_autotune_stats()
            reset_reblock_stats()
            t0 = time.perf_counter()
            kern2 = autotune_stage(
                v, "spmv", cache=PlanCache(root), include_reblock=True
            )
            t_warm = time.perf_counter() - t0
            wstats = autotune_stats()
            rstats = reblock_stats()
            assert wstats["benchmarks"] == 0, "warm restage must not measure"
            assert rstats["dp_runs"] == 0, "warm restage must not re-run the DP"
            np.testing.assert_allclose(
                np.asarray(kern2(v.val, x)), np.asarray(kern(v.val, x)),
                atol=3e-5, rtol=3e-5,
            )
            csv_row(
                f"reblock/{name}/warm_stage",
                t_warm * 1e6,
                f"benchmarks=0;dp_runs=0;"
                f"speedup={t_cold / max(t_warm, 1e-9):.1f}x",
            )
    clear_cache()


if __name__ == "__main__":
    main()
