"""Table III analog: SpMM (dense width 512 in the paper; scaled here).

SABLE staged backends vs gather-CSR and dense matmul baselines.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.staging import StagingOptions, stage_spmm

from .common import csr_spmm, csv_row, dense_spmm, paper_matrices, timeit


def run(scale: float = 0.1, n_cols: int = 128, zeros_pcts=(0, 20, 50),
        iters: int = 5) -> None:
    for zp in zeros_pcts:
        for name, v in paper_matrices(scale, zp):
            X = jnp.asarray(
                np.random.default_rng(0).standard_normal((v.shape[1], n_cols)),
                jnp.float32,
            )
            val = jnp.asarray(v.val)
            kc, cvals = csr_spmm(v)
            t_csr = timeit(kc, cvals, X, iters=iters)
            kd, dmat = dense_spmm(v)
            t_dense = timeit(kd, dmat, X, iters=iters)
            kg = stage_spmm(v, n_cols, StagingOptions(backend="grouped"))
            t_grouped = timeit(kg, val, X, iters=iters)
            csv_row(f"spmm/{name}/z{zp}/sable-grouped", t_grouped * 1e6,
                    f"{t_csr/t_grouped:.2f}x_vs_csr")
            csv_row(f"spmm/{name}/z{zp}/csr", t_csr * 1e6, "1.00x_vs_csr")
            csv_row(f"spmm/{name}/z{zp}/dense", t_dense * 1e6,
                    f"{t_csr/t_dense:.2f}x_vs_csr")


def main(quick: bool = False):
    run(scale=0.05 if quick else 0.1, n_cols=64 if quick else 128,
        zeros_pcts=(20,) if quick else (0, 20, 50), iters=3 if quick else 5)


if __name__ == "__main__":
    main()
