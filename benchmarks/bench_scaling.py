"""Figs 6/9 analog: parallel scaling of the blocked evaluation.

The paper measures thread scaling (1..16 threads).  This container has one
physical core, so wall-clock thread scaling is unmeasurable; what IS
measurable is the quantity that bounds it: the load balance of the paper's
Section IV-D block-row partitioning.  We report, for 1..16 workers,
``parallel efficiency upper bound = total_work / (workers * max_load)`` —
with perfect balance this is 1.0 and wall-clock scaling follows it on real
hardware.  ``us_per_call`` is the per-worker max load in FLOP-equivalents
scaled to the single-thread staged time, i.e. the projected step time.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import vbr as vbrlib
from repro.core.staging import StagingOptions, partition_block_rows, stage_spmv

from .common import csv_row, timeit


def run(n: int = 2000, iters: int = 8) -> None:
    for rs, cs, nb in [(20, 20, 50), (50, 50, 500), (100, 100, 2000)]:
        v = vbrlib.synthesize(n, n, rs, cs, nb, 0.2, False, seed=nb)
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
        k = stage_spmv(v, StagingOptions(backend="grouped"))
        t1 = timeit(k, jnp.asarray(v.val), x, iters=iters)
        sizes = np.zeros(v.num_block_rows, dtype=np.int64)
        for t in v.blocks():
            sizes[t.block_row] += t.size
        total = float(sizes.sum())
        for workers in (1, 2, 4, 8, 16):
            bins = partition_block_rows(v, workers)
            loads = [sum(float(sizes[a]) for a in b) for b in bins]
            max_load = max(loads) if loads else total
            eff = total / (workers * max_load) if max_load else 1.0
            projected = t1 * max_load / total
            csv_row(
                f"scaling/Matrix_{rs}_{cs}_{nb}/w{workers}",
                projected * 1e6,
                f"par_eff={eff:.3f}",
            )


def main(quick: bool = False):
    run(n=1000 if quick else 2000, iters=4 if quick else 8)


if __name__ == "__main__":
    main()
