"""2-D (shards x model) mesh factorization sweep for sharded staged SpMM.

Sweeps every (shards, model) factorization of 8 forced host devices —
(8,1), (4,2), (2,4), (1,8) — for the same structure and RHS width, with
the overlapped ppermute-ring gather on and off.  On forced host devices
(shared physical cores) wall-clock speedup is not expected; the sweep's
value is the relative cost of the factorizations (how much of the work
moves from the shard split to the column split) and a regression guard on
the 2-D path's compile/run health.  ``derived`` carries the partition
imbalance and the overlap flag next to the measured time.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import csv_row

_CHILD = """
import json, numpy as np, jax, jax.numpy as jnp
from repro.core import vbr as vbrlib
from repro.core.staging import stage_spmm
from repro.launch.mesh import make_staging_mesh
from benchmarks.common import timeit

quick = {quick}
n = 600 if quick else 2000
n_cols = 16
iters = 3 if quick else 8
rs, cs, nb = (24, 24, 90) if quick else (60, 60, 600)
v = vbrlib.synthesize(n, n, rs, cs, nb, 0.2, False, seed=nb)
rng = np.random.default_rng(0)
X = jnp.asarray(rng.standard_normal((n, n_cols)).astype(np.float32))
val = jnp.asarray(v.val)
rows = []
base = timeit(stage_spmm(v, n_cols), val, X, warmup=2, iters=iters)
rows.append({{"matrix": f"Matrix_{{rs}}_{{cs}}_{{nb}}", "shards": 0, "model": 0,
              "overlap": False, "spmm_s": base, "imbalance": 1.0}})
for shards, model in [(8, 1), (4, 2), (2, 4), (1, 8)]:
    mesh = make_staging_mesh((shards, model))
    for overlap in (True, False):
        k = stage_spmm(v, n_cols, mesh=mesh, overlap_gather=overlap)
        t = timeit(k, val, X, warmup=2, iters=iters)
        rows.append({{
            "matrix": f"Matrix_{{rs}}_{{cs}}_{{nb}}",
            "shards": shards,
            "model": model,
            "overlap": overlap,
            "spmm_s": t,
            "imbalance": k.imbalance(),
        }})
print("RESULT " + json.dumps(rows))
"""


def main(quick: bool = False) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ["src", env.get("PYTHONPATH", ""), "."] if p
    )
    out = subprocess.run(
        [sys.executable, "-c", _CHILD.format(quick=quick)],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    if out.returncode != 0:
        raise RuntimeError(
            f"mesh2d bench subprocess failed:\n{out.stdout}\n{out.stderr}"
        )
    rows = []
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            rows = json.loads(line[len("RESULT "):])
    base = next((r["spmm_s"] for r in rows if r["shards"] == 0), None)
    for r in rows:
        if r["shards"] == 0:
            csv_row(
                f"mesh2d/{r['matrix']}/spmm/unsharded", r["spmm_s"] * 1e6,
                "speedup=1.00",
            )
            continue
        csv_row(
            f"mesh2d/{r['matrix']}/spmm/s{r['shards']}m{r['model']}"
            f"{'o' if r['overlap'] else ''}",
            r["spmm_s"] * 1e6,
            f"speedup={base / max(r['spmm_s'], 1e-12):.2f},"
            f"imbalance={r['imbalance']:.3f},"
            f"overlap={int(r['overlap'])}",
        )


if __name__ == "__main__":
    main(quick=True)
