"""Figs 8/11 analog: codegen variants on matrices with very sparse blocks.

Paper setup: 500 VBR blocks, 300 at the sweep sparsity + 200 with only 10
non-zeros each.  Variants:
  full-block   loops over every stored block densely (baseline SABLE),
  hybrid       density-threshold staging (Listing 3): sparse blocks are
               unrolled into a COO tail, dense blocks stay regular.
The hybrid's win over full-block on these matrices is the paper's Fig 8.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import vbr as vbrlib
from repro.core.staging import StagingOptions, stage_spmv

from .common import csr_spmv, csv_row, timeit


def _mixed_matrix(n: int, sweep_sparsity: float, seed: int = 11) -> vbrlib.VBR:
    rng = np.random.default_rng(seed)
    v = vbrlib.synthesize(n, n, 50, 50, 500, sweep_sparsity, True, seed=seed)
    # make 200 of the 500 blocks nearly empty (10 nnz each), as in the paper
    tasks = list(v.blocks())
    idx = rng.permutation(len(tasks))[:200]
    val = v.val.copy()
    for i in idx:
        t = tasks[i]
        blk = np.zeros(t.size, val.dtype)
        nz = rng.permutation(t.size)[: min(10, t.size)]
        blk[nz] = rng.standard_normal(len(nz))
        val[t.val_offset : t.val_offset + t.size] = blk
    v.val = val
    return v


def run(n: int = 2000, iters: int = 8) -> None:
    for sweep in (0.0, 0.5):
        v = _mixed_matrix(n, sweep)
        x = jnp.asarray(np.random.default_rng(2).standard_normal(n), jnp.float32)
        val = jnp.asarray(v.val)
        k_full = stage_spmv(v, StagingOptions(backend="grouped"))
        t_full = timeit(k_full, val, x, iters=iters)
        k_hyb = stage_spmv(
            v, StagingOptions(backend="grouped", density_threshold=0.15)
        )
        assert k_hyb.coo is not None
        t_hyb = timeit(k_hyb, val, x, iters=iters)
        kc, cvals = csr_spmv(v)
        t_csr = timeit(kc, cvals, x, iters=iters)
        ref = np.asarray(v.to_dense() @ np.asarray(x))
        np.testing.assert_allclose(np.asarray(k_hyb(val, x)), ref, rtol=2e-3,
                                   atol=2e-3)
        csv_row(f"codegen/z{int(sweep*100)}/full-block", t_full * 1e6,
                f"{t_csr/t_full:.2f}x_vs_csr")
        csv_row(f"codegen/z{int(sweep*100)}/hybrid-unrolled", t_hyb * 1e6,
                f"{t_csr/t_hyb:.2f}x_vs_csr")


def main(quick: bool = False):
    run(n=1000 if quick else 2000, iters=4 if quick else 8)


if __name__ == "__main__":
    main()
