"""Data pipeline: deterministic, resumable, host-sharded, prefetched.

Fault-tolerance contract: an iterator's full state is ``{"step": int}`` —
batches are a pure function of (seed, step, host_shard), so restoring a
checkpoint and re-seeking the iterator reproduces the exact token stream
(no data loss or duplication across preemptions, and the stream is stable
under elastic re-sharding because sharding is applied at batch granularity).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

__all__ = ["SyntheticDataset", "FileDataset", "Prefetcher", "make_dataset"]


class SyntheticDataset:
    """Deterministic synthetic LM batches (counting + noise structure so a
    model can actually fit it in the e2e example)."""

    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        batch: int,
        seed: int = 0,
        host_id: int = 0,
        num_hosts: int = 1,
        frontend_dim: int = 0,
        src_len: int = 0,
    ):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch = batch  # per-host batch
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.frontend_dim = frontend_dim
        self.src_len = src_len
        self.step = 0

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, s: dict) -> None:
        self.step = int(s["step"])

    def _batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        # structured stream: ramps with random stride => learnable
        start = rng.integers(0, self.vocab_size, size=(self.batch, 1))
        stride = rng.integers(1, 7, size=(self.batch, 1))
        pos = np.arange(self.seq_len + 1)[None, :]
        toks = (start + stride * pos) % self.vocab_size
        out = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if self.frontend_dim:
            out["src_embeds"] = rng.standard_normal(
                (self.batch, self.src_len, self.frontend_dim)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        while True:
            b = self._batch_at(self.step)
            self.step += 1
            yield b


class FileDataset:
    """Memory-mapped binary token file (uint16/uint32), host-sharded,
    step-indexed windows => random access and exact resume."""

    def __init__(
        self,
        path: str,
        seq_len: int,
        batch: int,
        dtype=np.uint16,
        host_id: int = 0,
        num_hosts: int = 1,
        seed: int = 0,
    ):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.seq_len = seq_len
        self.batch = batch
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.seed = seed
        self.step = 0
        self.n_windows = (len(self.tokens) - 1) // seq_len

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, s: dict) -> None:
        self.step = int(s["step"])

    def _batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        perm = rng.integers(0, self.n_windows, size=(self.num_hosts, self.batch))
        idx = perm[self.host_id]
        toks = np.stack(
            [self.tokens[i * self.seq_len : i * self.seq_len + self.seq_len + 1]
             for i in idx]
        ).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        while True:
            b = self._batch_at(self.step)
            self.step += 1
            yield b


class Prefetcher:
    """Background-thread prefetch (depth-bounded queue)."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


def make_dataset(cfg, shape, seed=0, host_id=0, num_hosts=1, path: Optional[str] = None):
    """Dataset for (model cfg, input shape)."""
    per_host = max(shape.global_batch // num_hosts, 1)
    if path:
        return FileDataset(path, shape.seq_len, per_host, host_id=host_id,
                           num_hosts=num_hosts, seed=seed)
    kw = {}
    if cfg.is_encdec:
        from ..configs.shapes import src_len

        kw = {"frontend_dim": cfg.frontend_dim, "src_len": src_len(cfg, shape)}
    return SyntheticDataset(
        cfg.vocab_size, shape.seq_len, per_host, seed=seed, host_id=host_id,
        num_hosts=num_hosts, **kw,
    )
