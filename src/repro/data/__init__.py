from .pipeline import FileDataset, SyntheticDataset, Prefetcher, make_dataset
