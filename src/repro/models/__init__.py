"""Model zoo: configs + functional transformer/SSM/MoE implementations."""
from .config import (
    GroupSpec,
    LayerSpec,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SableConfig,
    SSMConfig,
    jamba_groups,
    param_count,
    uniform_groups,
)
from .transformer import (
    decode_step,
    encode,
    forward_train,
    init_cache,
    init_params,
    prefill,
    prefill_chunk,
)
