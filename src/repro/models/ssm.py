"""Mamba-2 (SSD — state-space duality) mixer.

Train/prefill uses the chunked SSD algorithm: intra-chunk 'attention-like'
quadratic term + inter-chunk recurrent state passing via ``lax.scan`` —
O(S * Q) work with chunk size Q, fully parallel within chunks (MXU-friendly
einsums).  Decode is the O(1) recurrent update on a (B, H, N, P) state.

Cache layout: {"conv": (B, d_conv-1, ch), "ssm": (B, H, N, P)}.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.ctx import MODEL, fetch
from .config import ModelConfig
from .layers import dense_init, rmsnorm

__all__ = ["mamba_init", "mamba_apply", "mamba_decode", "ssd_chunked"]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    gn = s.n_groups * s.d_state
    conv_ch = di + 2 * gn
    return s, di, nh, gn, conv_ch


def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    """Projections are stored unfused (z/x/B/C/dt separately) so each output
    dimension shards cleanly over the tensor axis (the fused layout would
    put shard boundaries inside the z/x/B/C split points)."""
    s, di, nh, gn, conv_ch = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "in_z": dense_init(ks[0], (d, di), dtype=dtype),
        "in_x": dense_init(ks[1], (d, di), dtype=dtype),
        "in_b": dense_init(ks[2], (d, gn), dtype=dtype),
        "in_c": dense_init(ks[3], (d, gn), dtype=dtype),
        "in_dt": dense_init(ks[4], (d, nh), dtype=dtype),
        "conv_w": dense_init(ks[5], (s.d_conv, conv_ch), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((nh,), dtype),  # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), dtype),
        "dt_bias": jnp.full((nh,), np.log(np.expm1(0.01)), dtype),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[6], (di, d), dtype=dtype),
    }


def _in_proj(p, x):
    """Apply the unfused input projections; returns (z, xbc, dt_raw)."""
    z = x @ fetch(p["in_z"].astype(x.dtype), None, MODEL)
    xbc = jnp.concatenate(
        [
            x @ fetch(p["in_x"].astype(x.dtype), None, MODEL),
            x @ fetch(p["in_b"].astype(x.dtype), None, MODEL),
            x @ fetch(p["in_c"].astype(x.dtype), None, MODEL),
        ],
        axis=-1,
    )
    dt_raw = x @ fetch(p["in_dt"].astype(x.dtype), None, MODEL)
    return z, xbc, dt_raw


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Depthwise causal conv, window d_conv.  xbc: (B, S, ch)."""
    d_conv, ch = w.shape
    out = jax.lax.conv_general_dilated(
        xbc,
        w[:, None, :].astype(xbc.dtype),  # (W, 1, ch)
        window_strides=(1,),
        padding=[(d_conv - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=ch,
    )
    return out + b.astype(xbc.dtype)


def ssd_chunked(xs, dt, A, B_, C_, chunk: int):
    """Chunked SSD.  xs: (B,S,H,P), dt: (B,S,H) (post-softplus), A: (H,)<0,
    B_/C_: (B,S,H,N).  Returns (y, final_state (B,H,N,P))."""
    Bb, S, H, P = xs.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        z = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        xs, dt, B_, C_ = z(xs), z(dt), z(B_), z(C_)
    Sp = S + pad
    nc = Sp // Q

    def c(t):  # chunkify: (B, S, ...) -> (B, nc, Q, ...)
        return t.reshape(Bb, nc, Q, *t.shape[2:])

    xs_c, dt_c, B_c, C_c = c(xs), c(dt), c(B_), c(C_)
    dA = dt_c * A  # (B,nc,Q,H), negative
    cums = jnp.cumsum(dA, axis=2)  # inclusive

    # intra-chunk: y_i += sum_{j<=i} exp(cums_i - cums_j) dt_j (C_i.B_j) x_j
    diff = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # (B,nc,Q,Q,H) [i,j]
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.where(tri, jnp.exp(diff), 0.0).astype(xs.dtype)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", C_c, B_c)
    xdt = xs_c * dt_c[..., None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", cb * L, xdt)

    # per-chunk outgoing state: sum_j exp(cums_Q - cums_j) B_j (dt_j x_j)
    decay_end = jnp.exp(cums[:, :, -1:, :] - cums)  # (B,nc,Q,H)
    states = jnp.einsum(
        "bcjhn,bcjhp->bchnp", B_c * decay_end[..., None].astype(xs.dtype), xdt
    )

    # inter-chunk scan over nc
    csum = cums[:, :, -1, :]  # (B,nc,H)
    def step(carry, inp):
        s_c, dAc = inp
        new = carry * jnp.exp(dAc)[..., None, None].astype(carry.dtype) + s_c
        return new, carry  # emit state at chunk START

    final, starts = jax.lax.scan(
        step,
        jnp.zeros((Bb, H, N, P), xs.dtype),
        (states.transpose(1, 0, 2, 3, 4), csum.transpose(1, 0, 2)),
    )
    starts = starts.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P)
    y_inter = jnp.einsum(
        "bcihn,bchnp->bcihp",
        C_c * jnp.exp(cums)[..., None].astype(xs.dtype),
        starts,
    )
    y = (y_intra + y_inter).reshape(Bb, Sp, H, P)
    return y[:, :S], final


def mamba_apply(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    return_cache: bool = False,
):
    """Full-sequence forward (train / prefill).  Returns (out, cache|None)."""
    s, di, nh, gn, conv_ch = _dims(cfg)
    Bb, S, d = x.shape
    z, xbc, dt_raw = _in_proj(p, x)

    conv_out = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    conv_act = jax.nn.silu(conv_out)
    xs = conv_act[..., :di]
    B_ = conv_act[..., di : di + gn].reshape(Bb, S, s.n_groups, s.d_state)
    C_ = conv_act[..., di + gn :].reshape(Bb, S, s.n_groups, s.d_state)
    rep = nh // s.n_groups
    B_h = jnp.repeat(B_, rep, axis=2)
    C_h = jnp.repeat(C_, rep, axis=2)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"]).astype(x.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(x.dtype)
    xh = xs.reshape(Bb, S, nh, s.head_dim)
    y, final_state = ssd_chunked(xh, dt, A, B_h, C_h, s.chunk)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bb, S, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ fetch(p["out_proj"].astype(x.dtype), MODEL, None)

    cache = None
    if return_cache:
        # conv state: last (d_conv-1) pre-activation conv inputs
        tail = xbc[:, -(s.d_conv - 1) :, :]
        pad = s.d_conv - 1 - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        cache = {"conv": tail, "ssm": final_state}
    return out, cache


def mamba_decode(p: dict, x: jnp.ndarray, cfg: ModelConfig, cache: dict):
    """Single-token recurrent step.  x: (B, 1, d)."""
    s, di, nh, gn, conv_ch = _dims(cfg)
    Bb = x.shape[0]
    z, xbc, dt_raw = _in_proj(p, x[:, 0])

    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B,dc,ch)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(x.dtype), p["conv_w"].astype(x.dtype))
    conv_act = jax.nn.silu(conv_out + p["conv_b"].astype(x.dtype))
    new_conv = window[:, 1:]

    xs = conv_act[..., :di]
    B_ = conv_act[..., di : di + gn].reshape(Bb, s.n_groups, s.d_state)
    C_ = conv_act[..., di + gn :].reshape(Bb, s.n_groups, s.d_state)
    rep = nh // s.n_groups
    B_h = jnp.repeat(B_, rep, axis=1)  # (B,H,N)
    C_h = jnp.repeat(C_, rep, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"]).astype(x.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(x.dtype)
    xh = xs.reshape(Bb, nh, s.head_dim)

    dA = jnp.exp(dt * A)  # (B,H)
    sstate = cache["ssm"]
    new_state = sstate * dA[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", B_h, xh * dt[..., None]
    )
    y = jnp.einsum("bhn,bhnp->bhp", C_h, new_state)
    y = y + xh * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(Bb, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ fetch(p["out_proj"].astype(x.dtype), MODEL, None))[:, None, :]
    return out, {"conv": new_conv, "ssm": new_state}
