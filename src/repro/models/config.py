"""Model configuration system.

A model is a stack of *groups*; each group repeats a *block* of sub-layers
(`LayerSpec`s) ``repeat`` times via ``lax.scan`` over stacked parameters.
This single abstraction expresses every assigned architecture:

  uniform LM        [(L, [attn+ffn])]
  deepseek-v2       [(1, [attn+dense]), (59, [attn+moe])]
  jamba             [(9, [7x mamba + 1x attn, ffn/moe alternating])]
  enc-dec           encoder groups + decoder groups (cross-attn)

Scan keeps the HLO O(#distinct blocks), which is what makes 512-device
dry-run compiles of 60-layer 236B models tractable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int  # per-expert hidden size
    num_shared: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    aux_loss_coef: float = 0.001
    # dropless routed FFN: full-capacity buckets (nothing dropped) with the
    # expert FFN computed block-sparsely over OCCUPIED capacity blocks only
    # (kernels.bsr_ops sdd/dsd) — FLOPs track actual tokens, not E*C
    dropless: bool = False
    dropless_block: int = 8  # capacity-slot block rows per sparse block


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class SableConfig:
    """Block-sparse (SABLE-staged) weights for FFN matrices."""

    block_m: int = 128  # tile rows (input dim)
    block_n: int = 128  # tile cols (output dim)
    density: float = 0.25  # fraction of blocks kept
    target: str = "ffn"  # which matrices to sparsify
    backend: str = "grouped"  # grouped | pallas
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "gqa"  # gqa | mla | mamba | none
    ffn: str = "dense"  # dense | moe | none
    cross_attn: bool = False  # decoder cross-attention


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    repeat: int
    layers: tuple  # tuple[LayerSpec, ...]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | hybrid | moe | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    groups: tuple  # tuple[GroupSpec, ...] — decoder (or decoder-only) stack
    enc_groups: tuple = ()  # encoder stack (enc-dec models)
    ffn_type: str = "swiglu"  # swiglu | relu2 | gelu
    attn_type: str = "gqa"  # gqa | mla
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    sable: Optional[SableConfig] = None
    qk_norm: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 131072
    causal: bool = True
    # modality frontend stub: inputs are precomputed frame/patch embeddings
    frontend_dim: int = 0  # 0 => token ids; >0 => embeddings of this dim
    # numerics / schedule
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "none"  # none | full | dots
    logit_softcap: float = 0.0
    attn_chunk: int = 0  # >0: flash-style chunked attention (chunk size)

    # ------------------------------------------------------------------ #
    @property
    def n_layers(self) -> int:
        return sum(g.repeat * len(g.layers) for g in self.groups) + sum(
            g.repeat * len(g.layers) for g in self.enc_groups
        )

    @property
    def is_encdec(self) -> bool:
        return len(self.enc_groups) > 0

    def has_mixer(self, kind: str) -> bool:
        for g in tuple(self.groups) + tuple(self.enc_groups):
            for s in g.layers:
                if s.mixer == kind:
                    return True
        return False

    @property
    def attention_free(self) -> bool:
        return not (self.has_mixer("gqa") or self.has_mixer("mla"))

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid / linear-attn)."""
        return self.family in ("ssm", "hybrid")


def uniform_groups(n_layers: int, spec: LayerSpec) -> tuple:
    return (GroupSpec(repeat=n_layers, layers=(spec,)),)


def jamba_groups(n_super: int, attn_pos: int = 7, moe_stride: int = 2) -> tuple:
    """1 attention : 7 mamba per super-block; MoE every ``moe_stride``."""
    layers = []
    for i in range(8):
        mixer = "gqa" if i == attn_pos else "mamba"
        ffn = "moe" if (i % moe_stride == 1) else "dense"
        layers.append(LayerSpec(mixer=mixer, ffn=ffn))
    return (GroupSpec(repeat=n_super, layers=tuple(layers)),)


# ---------------------------------------------------------------------- #
# Parameter counting (for roofline MODEL_FLOPS = 6 N D)
# ---------------------------------------------------------------------- #
def _layer_params(cfg: ModelConfig, spec: LayerSpec, active: bool) -> int:
    d = cfg.d_model
    n = 0
    if spec.mixer == "gqa":
        n += d * cfg.n_heads * cfg.head_dim  # wq
        n += 2 * d * cfg.n_kv_heads * cfg.head_dim  # wk, wv
        n += cfg.n_heads * cfg.head_dim * d  # wo
    elif spec.mixer == "mla":
        m = cfg.mla
        n += d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * (
            m.qk_nope_dim + m.qk_rope_dim
        )
        n += d * (m.kv_lora_rank + m.qk_rope_dim)
        n += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)
        n += cfg.n_heads * m.v_head_dim * d
    elif spec.mixer == "mamba":
        s = cfg.ssm
        di = s.d_inner(d)
        nh = s.n_heads(d)
        gs = s.n_groups * s.d_state
        n += d * (2 * di + 2 * gs + nh)  # in_proj
        n += (di + 2 * gs) * s.d_conv  # conv
        n += di * d  # out_proj
        n += 3 * nh + di  # A_log, D, dt_bias, norm
    if spec.cross_attn:
        n += 2 * d * cfg.n_heads * cfg.head_dim + 2 * d * cfg.n_kv_heads * cfg.head_dim
    if spec.ffn == "dense":
        mult = 3 if cfg.ffn_type == "swiglu" else 2
        n += mult * d * cfg.d_ff
    elif spec.ffn == "moe":
        mc = cfg.moe
        mult = 3 if cfg.ffn_type == "swiglu" else 2
        per_expert = mult * d * mc.d_ff
        routed = mc.top_k if active else mc.num_experts
        n += routed * per_expert
        n += mc.num_shared * mult * d * (mc.shared_d_ff or mc.d_ff)
        n += d * mc.num_experts  # router
    n += 2 * d  # norms
    return n


def param_count(cfg: ModelConfig, active: bool = False) -> int:
    """Total (or active, for MoE) parameter count."""
    n = cfg.vocab_size * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * cfg.d_model
    for g in tuple(cfg.enc_groups) + tuple(cfg.groups):
        for spec in g.layers:
            n += g.repeat * _layer_params(cfg, spec, active)
    n += cfg.d_model  # final norm
    return n
