"""Model assembly: groups of scanned blocks -> LM / enc-dec forward passes.

Public entry points (all pure functions of (params, cfg, inputs)):

  init_params(cfg, key)                         -> params pytree
  forward_train(params, cfg, batch)             -> (logits, aux_loss)
  init_cache(cfg, batch, s_max, dtype)          -> cache pytree
  prefill(params, cfg, tokens, cache)           -> (logits, cache)
  decode_step(params, cfg, token, cache, pos)   -> (logits, cache)
  encode(params, cfg, src_embeds)               -> enc_out  (enc-dec only)

``lax.scan`` over stacked per-group parameters keeps HLO size independent
of depth; caches are stacked along the same axis and threaded through scan
as xs/ys.  Remat policy from cfg.remat wraps each block body.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.ctx import DP, MODEL, anchor_params, constrain, fetch
from .attention import (
    cross_apply,
    cross_init,
    cross_kv,
    gqa_apply,
    gqa_init,
    mla_apply,
    mla_init,
)
from .config import GroupSpec, LayerSpec, ModelConfig
from .layers import dense_init, ffn_apply, ffn_init, rmsnorm
from .moe import moe_apply, moe_init
from .ssm import mamba_apply, mamba_decode, mamba_init

__all__ = [
    "init_params",
    "forward_train",
    "init_cache",
    "prefill",
    "decode_step",
    "encode",
]


def _cdtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _pdtype(cfg):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------- #
# Init
# ---------------------------------------------------------------------- #
def _sublayer_init(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 4)
    p = {}
    if spec.mixer == "gqa":
        p["mixer"] = gqa_init(ks[0], cfg, dtype)
        p["ln_mixer"] = jnp.ones((cfg.d_model,), dtype)
    elif spec.mixer == "mla":
        p["mixer"] = mla_init(ks[0], cfg, dtype)
        p["ln_mixer"] = jnp.ones((cfg.d_model,), dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_init(ks[0], cfg, dtype)
        p["ln_mixer"] = jnp.ones((cfg.d_model,), dtype)
    if spec.cross_attn:
        p["cross"] = cross_init(ks[1], cfg, dtype)
        p["ln_cross"] = jnp.ones((cfg.d_model,), dtype)
    if spec.ffn == "dense":
        p["ffn"] = ffn_init(ks[2], cfg, dtype=dtype)
        p["ln_ffn"] = jnp.ones((cfg.d_model,), dtype)
    elif spec.ffn == "moe":
        p["moe"] = moe_init(ks[3], cfg, dtype)
        p["ln_ffn"] = jnp.ones((cfg.d_model,), dtype)
    return p


def _block_init(key, cfg, specs, dtype):
    ks = jax.random.split(key, len(specs))
    return {f"sub{i}": _sublayer_init(ks[i], cfg, s, dtype) for i, s in enumerate(specs)}


def _group_init(key, cfg, g: GroupSpec, dtype):
    keys = jax.random.split(key, g.repeat)
    return jax.vmap(lambda k: _block_init(k, cfg, g.layers, dtype))(keys)


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = _pdtype(cfg)
    n_groups = len(cfg.groups) + len(cfg.enc_groups)
    ks = jax.random.split(key, n_groups + 3)
    params = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), 0.02, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "groups": [
            _group_init(ks[3 + i], cfg, g, dtype) for i, g in enumerate(cfg.groups)
        ],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), 0.02, dtype)
    if cfg.is_encdec:
        off = 3 + len(cfg.groups)
        params["enc_groups"] = [
            _group_init(
                jax.random.fold_in(ks[2], i), cfg, g, dtype
            )
            for i, g in enumerate(cfg.enc_groups)
        ]
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), dtype)
        if cfg.frontend_dim and cfg.frontend_dim != cfg.d_model:
            params["frontend_proj"] = dense_init(
                ks[2], (cfg.frontend_dim, cfg.d_model), dtype=dtype
            )
    return params


# ---------------------------------------------------------------------- #
# Block apply (one scan step)
# ---------------------------------------------------------------------- #
def _block_apply(
    cfg: ModelConfig,
    specs,
    p_slice: dict,
    x,
    positions,
    cache_slice: Optional[dict],
    cache_pos,
    causal: bool,
    enc_out,
    mode: str,
):
    new_cache = {}
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps
    for i, spec in enumerate(specs):
        p = p_slice[f"sub{i}"]
        c = cache_slice.get(f"sub{i}") if cache_slice is not None else None
        if spec.mixer in ("gqa", "mla"):
            h = rmsnorm(x, p["ln_mixer"], eps)
            fn = gqa_apply if spec.mixer == "gqa" else mla_apply
            o, nc = fn(
                p["mixer"],
                h,
                cfg,
                positions,
                cache=c.get("attn") if c else None,
                cache_pos=cache_pos,
                causal=causal,
            )
            x = x + o
            if c is not None:
                new_cache.setdefault(f"sub{i}", {})["attn"] = nc
        elif spec.mixer == "mamba":
            h = rmsnorm(x, p["ln_mixer"], eps)
            if mode == "decode":
                o, nc = mamba_decode(p["mixer"], h, cfg, c["ssm_cache"])
            else:
                o, nc = mamba_apply(
                    p["mixer"], h, cfg, return_cache=(c is not None)
                )
            x = x + o
            if c is not None:
                new_cache.setdefault(f"sub{i}", {})["ssm_cache"] = nc
        if spec.cross_attn:
            h = rmsnorm(x, p["ln_cross"], eps)
            if c is not None and "cross" in c and mode == "decode":
                kv = c["cross"]
            else:
                kv = cross_kv(p["cross"], enc_out, cfg)
            x = x + cross_apply(p["cross"], h, kv, cfg)
            if c is not None:
                new_cache.setdefault(f"sub{i}", {})["cross"] = kv
        if spec.ffn == "dense":
            x = x + ffn_apply(p["ffn"], rmsnorm(x, p["ln_ffn"], eps), cfg)
        elif spec.ffn == "moe":
            y, a = moe_apply(p["moe"], rmsnorm(x, p["ln_ffn"], eps), cfg)
            x = x + y
            aux = aux + a
        # keep the residual stream batch-sharded between sub-layers so the
        # SPMD partitioner never round-trips it through other layouts
        x = constrain(x, DP, None, None)
    return x, new_cache, aux


def _run_groups(
    cfg: ModelConfig,
    groups,
    group_params,
    x,
    positions,
    caches,
    cache_pos,
    causal,
    enc_out,
    mode: str,
):
    """Scan each group's stacked params (and cache) over its repeat dim."""
    new_caches = []
    aux_total = jnp.zeros((), jnp.float32)
    for gi, g in enumerate(groups):
        specs = g.layers
        gp = group_params[gi]
        gc = caches[gi] if caches is not None else None

        def body(carry, xs):
            x, aux = carry
            p_slice, c_slice = xs
            # pin the dynamic-sliced layer weights to their storage layout
            # before the TP-layout fetches (see ctx.anchor_params)
            p_slice = anchor_params(p_slice)
            out, nc, a = _block_apply(
                cfg, specs, p_slice, x, positions, c_slice, cache_pos,
                causal, enc_out, mode,
            )
            return (out, aux + a), nc

        if cfg.remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        elif cfg.remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                prevent_cse=False,
            )

        (x, aux_total), nc_stack = jax.lax.scan(
            body, (x, aux_total), (gp, gc)
        )
        new_caches.append(nc_stack)
    return x, new_caches, aux_total


# ---------------------------------------------------------------------- #
# Cache construction
# ---------------------------------------------------------------------- #
def _sub_cache(cfg: ModelConfig, spec: LayerSpec, batch, s_max, enc_len, dtype):
    c = {}
    if spec.mixer == "gqa":
        kv = (batch, s_max, cfg.n_kv_heads, cfg.head_dim)
        c["attn"] = {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}
    elif spec.mixer == "mla":
        m = cfg.mla
        c["attn"] = {
            "ckv": jnp.zeros((batch, s_max, m.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, s_max, m.qk_rope_dim), dtype),
        }
    elif spec.mixer == "mamba":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        ch = di + 2 * s.n_groups * s.d_state
        c["ssm_cache"] = {
            "conv": jnp.zeros((batch, s.d_conv - 1, ch), dtype),
            "ssm": jnp.zeros(
                (batch, s.n_heads(cfg.d_model), s.d_state, s.head_dim), dtype
            ),
        }
    if spec.cross_attn:
        kv = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
        c["cross"] = {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}
    return c


def init_cache(cfg: ModelConfig, batch: int, s_max: int, enc_len: int = 0, dtype=None):
    """Decode-capacity cache, stacked (repeat, ...) per group."""
    dtype = dtype or _cdtype(cfg)

    def one_group(g: GroupSpec):
        block = {
            f"sub{i}": _sub_cache(cfg, s, batch, s_max, enc_len, dtype)
            for i, s in enumerate(g.layers)
            if _sub_cache(cfg, s, batch, s_max, enc_len, dtype)
        }
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (g.repeat,) + a.shape), block
        )

    return [one_group(g) for g in cfg.groups]


# ---------------------------------------------------------------------- #
# Entry points
# ---------------------------------------------------------------------- #
def _embed(params, cfg, tokens):
    x = fetch(params["embed"].astype(_cdtype(cfg)), MODEL, None)[tokens]
    return constrain(x, DP, None, None)


def _unembed(params, cfg, x):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ fetch(params["embed"].astype(x.dtype), MODEL, None).T
    else:
        logits = x @ fetch(params["lm_head"].astype(x.dtype), None, MODEL)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return constrain(logits, DP, None, MODEL)  # vocab-sharded logits


def encode(params, cfg: ModelConfig, src_embeds):
    """Encoder stack over precomputed frontend embeddings (B, S_src, D)."""
    x = src_embeds.astype(_cdtype(cfg))
    if "frontend_proj" in params:
        x = x @ params["frontend_proj"].astype(x.dtype)
    positions = jnp.arange(x.shape[1])
    x, _, _ = _run_groups(
        cfg, cfg.enc_groups, params["enc_groups"], x, positions,
        None, None, False, None, "train",
    )
    return rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def forward_train(params, cfg: ModelConfig, batch: dict):
    """Teacher-forced forward.  batch: {"tokens": (B,S)} and, for enc-dec,
    {"src_embeds": (B,S_src,frontend_dim)}.  Returns (logits, aux)."""
    tokens = batch["tokens"]
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["src_embeds"])
    x = _embed(params, cfg, tokens)
    positions = jnp.arange(tokens.shape[1])
    x, _, aux = _run_groups(
        cfg, cfg.groups, params["groups"], x, positions, None, None,
        cfg.causal, enc_out, "train",
    )
    return _unembed(params, cfg, x), aux


def prefill(params, cfg: ModelConfig, tokens, cache, enc_out=None):
    """Process the prompt, filling the cache at positions [0, S)."""
    x = _embed(params, cfg, tokens)
    positions = jnp.arange(tokens.shape[1])
    x, new_cache, _ = _run_groups(
        cfg, cfg.groups, params["groups"], x, positions, cache, 0,
        cfg.causal, enc_out, "prefill",
    )
    return _unembed(params, cfg, x[:, -1:]), new_cache


def prefill_chunk(params, cfg: ModelConfig, tokens, cache, start, enc_out=None):
    """Process prompt positions [start, start+S), writing the cache at the
    same offsets and attending over every cached position <= each query
    (`cache_pos` drives both the write offset and the causal-mask offset in
    the attention layers).  With start == 0 this is exactly ``prefill``.

    Only valid for models whose cache is entirely attention KV: a Mamba/SSM
    sub-layer in "prefill" mode recomputes its state from scratch over just
    this chunk, so chunked callers (the serving scheduler) must gate on a
    fully-paged cache."""
    x = _embed(params, cfg, tokens)
    positions = start + jnp.arange(tokens.shape[1])
    x, new_cache, _ = _run_groups(
        cfg, cfg.groups, params["groups"], x, positions, cache, start,
        cfg.causal, enc_out, "prefill",
    )
    return _unembed(params, cfg, x[:, -1:]), new_cache


def decode_step(params, cfg: ModelConfig, token, cache, pos, enc_out=None):
    """One decode step.  token: (B, 1) int32, pos: scalar int32 position."""
    x = _embed(params, cfg, token)
    positions = jnp.full((token.shape[0], 1), pos, dtype=jnp.int32)
    x, new_cache, _ = _run_groups(
        cfg, cfg.groups, params["groups"], x, positions, cache, pos,
        cfg.causal, enc_out, "decode",
    )
    return _unembed(params, cfg, x), new_cache
