"""Mixture-of-Experts with top-k routing, capacity buckets and EP sharding.

Dispatch is the sort-free scatter formulation: each (token, k) assignment
gets a slot inside its expert's capacity bucket via a masked cumulative sum;
tokens beyond capacity are dropped (capacity_factor controls the trade).
The (E, C, d) buffers are what XLA SPMD reshards across the model axis
(expert parallelism) — the all-to-all shows up explicitly in the dry-run
HLO and is counted by the roofline.

Shared experts (DeepSeek-style) are a dense FFN branch added to the routed
output.  The router aux (load-balance) loss follows Switch/DeepSeek.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.ctx import DP, MODEL, constrain, fetch
from .config import ModelConfig
from .layers import _act, dense_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    mc = cfg.moe
    d, E, f = cfg.d_model, mc.num_experts, mc.d_ff
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, E), scale=0.02, dtype=dtype),
        "w1": dense_init(ks[1], (E, d, f), dtype=dtype),
        "w2": dense_init(ks[2], (E, f, d), dtype=dtype),
    }
    if cfg.ffn_type == "swiglu":
        p["w3"] = dense_init(ks[3], (E, d, f), dtype=dtype)
    if mc.num_shared:
        sf = (mc.shared_d_ff or mc.d_ff) * mc.num_shared
        p["sw1"] = dense_init(ks[4], (d, sf), dtype=dtype)
        p["sw2"] = dense_init(ks[5], (sf, d), dtype=dtype)
        if cfg.ffn_type == "swiglu":
            p["sw3"] = dense_init(ks[6], (d, sf), dtype=dtype)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    mc = cfg.moe
    if mc.dropless:
        # full capacity: an expert can receive at most one slot per token
        # (top_k indices are distinct), so C = tokens guarantees no drops;
        # round to the sparse block size so capacity blocks tile exactly
        bm = mc.dropless_block
        return max(bm, -(-tokens // bm) * bm)
    c = int(np.ceil(tokens * mc.top_k / mc.num_experts * mc.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def _dropless_ffn(p: dict, buf: jnp.ndarray, counts: jnp.ndarray,
                  tokens: int, cfg: ModelConfig) -> jnp.ndarray:
    """Expert FFN over only the OCCUPIED capacity blocks.

    The (G, E, C, d) buffer is viewed as one tall dense matrix of
    (dropless_block, d) row-blocks; each (group, expert) bucket occupies
    ``ceil(count/bm)`` of them.  With the per-expert weights stacked
    side-by-side as (d, E*f), the routed first matmul is exactly ``sdd``
    under the topology "bucket row-block x its expert's column-block"
    (inspection-free: the mask is derived in-trace from the routing
    counts), the activation runs elementwise on the block data, and
    ``dsd`` against the stacked (E*f, d) second weights maps back to the
    buffer.  Unvisited (empty) capacity blocks come back as zero rows, so
    the combine gather is unchanged.  FLOPs scale with occupied blocks
    (~ tokens * top_k), not with the dense E*C buffer.
    """
    from ..kernels.bsr_ops import dsd, sdd
    from ..sparse.block_csr import topology_from_mask

    mc = cfg.moe
    G, E, C, d = buf.shape
    f = p["w1"].shape[-1]
    bm = mc.dropless_block
    Cb = C // bm

    occ = -(-counts // bm)  # (G, E) blocks needed per bucket
    occ_mask = jnp.arange(Cb)[None, None, :] < occ[:, :, None]  # (G, E, Cb)
    eye = jnp.eye(E, dtype=bool)  # bucket (g, e) multiplies expert e only
    mask = (occ_mask[..., None] & eye[None, :, None, :]).reshape(G * E * Cb, E)
    # each expert wastes at most one partial block per group
    nnz_max = G * min(E * Cb, -(-tokens * mc.top_k // bm) + E)
    topo = topology_from_mask(mask, (bm, f), nnz_max=nnz_max)

    a = buf.reshape(G * E * C, d)
    w1 = fetch(p["w1"].astype(buf.dtype), None, None, None)
    h = sdd(a, jnp.transpose(w1, (1, 0, 2)).reshape(d, E * f), topo)
    if cfg.ffn_type == "swiglu":
        w3 = fetch(p["w3"].astype(buf.dtype), None, None, None)
        g = sdd(a, jnp.transpose(w3, (1, 0, 2)).reshape(d, E * f), topo)
        h = h.with_data(jax.nn.silu(h.data) * g.data)
    else:
        h = h.with_data(_act(cfg, h.data))
    w2 = fetch(p["w2"].astype(buf.dtype), None, None, None)
    out = dsd(h, w2.reshape(E * f, d))
    return out.reshape(G, E, C, d)


def moe_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux_loss).

    GShard-style grouped dispatch (§Perf iteration on deepseek-v2): tokens
    are bucketed per GROUP (= batch row, which is data-sharded), so the
    scatter into and gather out of the capacity buffer are LOCAL to each
    data shard — only the dense (G, E, Cg, d) buffer crosses the mesh (a
    clean all-to-all the partitioner handles), never gather/scatter
    semantics.  The global-buffer path had XLA lowering cross-shard
    scatters as replicate+all-reduce (~2 TB/device/step on deepseek-v2).

    Single-token decode (S == 1) keeps one global group: per-group
    capacity would pad E*Cg >> T there.
    """
    mc = cfg.moe
    B, S, d = x.shape
    E, k = mc.num_experts, mc.top_k
    if S > 1:
        G, Tg = B, S  # groups = batch rows (data-sharded)
    else:
        G, Tg = 1, B * S
    C = _capacity(Tg, cfg)
    xg = x.reshape(G, Tg, d)

    logits = (
        xg @ fetch(p["router"].astype(xg.dtype), None, None)
    ).astype(jnp.float32)  # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # (G, Tg, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # slot within the (group, expert) bucket via per-group masked cumsum
    flat_idx = idx.reshape(G, Tg * k)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)  # (G, Tg*k, E)
    slot_flat = jnp.cumsum(onehot, axis=1) - onehot
    slot = jnp.take_along_axis(slot_flat, flat_idx[..., None], axis=2)[..., 0]
    slot = slot.reshape(G, Tg, k)
    dropped = slot >= C
    slot = jnp.where(dropped, C, slot)  # OOB => scatter mode='drop'

    # dispatch: local scatter into (G, E, Cg, d).  vmap over G keeps the
    # group dim a BATCH dim of the scatter, so the partitioner shards it
    # over dp instead of replicating (explicit 3-D index arrays defeat
    # batch-dim detection and cost ~80 TB/device — §Perf iteration log).
    xk = jnp.broadcast_to(xg[:, :, None, :], (G, Tg, k, d))
    buf = jax.vmap(
        lambda i, s, v: jnp.zeros((E, C, d), xg.dtype).at[i, s].set(
            v, mode="drop"
        )
    )(idx, slot, xk)
    buf = constrain(buf, DP, MODEL, None, None)

    if mc.dropless:
        # dropless: FFN only over occupied capacity blocks (block-sparse
        # sdd/dsd over an in-trace topology; single flattened matrix, so
        # no EP resharding — the dropless path is the per-batch-topology
        # regime, not the EP-sharded dense-buffer one)
        counts = onehot.sum(axis=1)  # (G, E) tokens routed per bucket
        out_buf = _dropless_ffn(p, buf, counts, Tg, cfg)
    else:
        # expert FFN: batched einsum; E sharded over 'model' (EP) — the
        # (G@dp, E, C, d) -> (G, E@model, C, d) reshard is the EP all-to-all
        h = jnp.einsum("gecd,edf->gecf", buf,
                       fetch(p["w1"].astype(xg.dtype), MODEL, None, None))
        if cfg.ffn_type == "swiglu":
            g = jnp.einsum("gecd,edf->gecf", buf,
                           fetch(p["w3"].astype(xg.dtype), MODEL, None, None))
            h = jax.nn.silu(h) * g
        else:
            h = _act(cfg, h)
        out_buf = jnp.einsum("gecf,efd->gecd", h,
                             fetch(p["w2"].astype(xg.dtype), MODEL, None, None))
    # return expert outputs to the data shards BEFORE the combine gather:
    # an explicit all-gather over 'model' of the dense buffer (~0.3 GB per
    # group) so the gather below stays local — letting the partitioner
    # handle an E-sharded gather costs ~5x more (replicate+AR of (T,k,d))
    out_buf = constrain(out_buf, DP, None, None, None)

    # combine: local gather per group; dropped tokens contribute zero
    gathered = jax.vmap(
        lambda b, i, s: b.at[i, s].get(mode="fill", fill_value=0)
    )(out_buf, idx, slot)  # (G, Tg, k, d)
    gathered = constrain(gathered, DP, None, None, None)
    y = (gathered * gate[..., None].astype(xg.dtype)).sum(axis=2)
    y = y.reshape(B * S, d)
    xt = x.reshape(B * S, d)

    # shared experts (dense branch)
    if mc.num_shared:
        h = xt @ fetch(p["sw1"].astype(xt.dtype), None, MODEL)
        if cfg.ffn_type == "swiglu":
            h = jax.nn.silu(h) * (xt @ fetch(p["sw3"].astype(xt.dtype), None, MODEL))
        else:
            h = _act(cfg, h)
        y = y + h @ fetch(p["sw2"].astype(xt.dtype), MODEL, None)

    # Switch-style load-balance aux loss
    me = probs.reshape(-1, E).mean(axis=0)  # mean router prob per expert
    ce = jnp.bincount(flat_idx.reshape(-1), length=E).astype(jnp.float32) / (
        G * Tg * k
    )
    aux = mc.aux_loss_coef * E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux
