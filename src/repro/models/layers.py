"""Shared layer primitives: norms, RoPE, FFNs (dense + SABLE-sparse)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.ctx import MODEL, fetch
from ..sparse.linear import random_pattern, sparse_matmul_auto
from .config import ModelConfig

__all__ = [
    "rmsnorm",
    "rope",
    "ffn_apply",
    "ffn_init",
    "dense_init",
    "sable_patterns",
]


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embeddings.  x: (B, S, H, D), positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- #
# FFN
# ---------------------------------------------------------------------- #
def dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def sable_patterns(cfg: ModelConfig) -> dict:
    """Static block patterns for the sparsified FFN matrices (shared across
    layers — one staged executable pattern serves the whole stack)."""
    sb = cfg.sable
    pat_in = random_pattern(
        cfg.d_model, cfg.d_ff, sb.block_m, sb.block_n, sb.density, seed=sb.seed
    )
    pat_out = random_pattern(
        cfg.d_ff, cfg.d_model, sb.block_n, sb.block_m, sb.density, seed=sb.seed + 1
    )
    return {"in": pat_in, "out": pat_out}


def ffn_init(key, cfg: ModelConfig, d_ff: int = None, dtype=jnp.float32) -> dict:
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.sable is not None and cfg.sable.target == "ffn":
        pats = sable_patterns(cfg)
        p_in, p_out = pats["in"], pats["out"]
        out = {
            "w1": dense_init(ks[0], (p_in.n_tiles, p_in.tm, p_in.tk), 1 / np.sqrt(d), dtype),
            "w2": dense_init(ks[1], (p_out.n_tiles, p_out.tm, p_out.tk), 1 / np.sqrt(d_ff), dtype),
        }
        if cfg.ffn_type == "swiglu":
            out["w3"] = dense_init(
                ks[2], (p_in.n_tiles, p_in.tm, p_in.tk), 1 / np.sqrt(d), dtype
            )
        return out
    out = {
        "w1": dense_init(ks[0], (d, d_ff), dtype=dtype),
        "w2": dense_init(ks[1], (d_ff, d), dtype=dtype),
    }
    if cfg.ffn_type == "swiglu":
        out["w3"] = dense_init(ks[2], (d, d_ff), dtype=dtype)
    return out


def _act(cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    if cfg.ffn_type == "relu2":  # nemotron squared-ReLU
        r = jax.nn.relu(h)
        return r * r
    if cfg.ffn_type == "gelu":
        return jax.nn.gelu(h)
    return jax.nn.silu(h)


def ffn_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Dense or SABLE block-sparse FFN (swiglu / relu^2 / gelu)."""
    if cfg.sable is not None and p["w1"].ndim == 3:
        pats = sable_patterns(cfg)
        p_in, p_out = pats["in"], pats["out"]
        # out_model: the d_ff intermediate is the tensor-parallel dim — the
        # constraint resolves through the activation_sharding ctx (no-op
        # outside), matching the MODEL-sharded tiles fetched below
        h = sparse_matmul_auto(
            x, fetch(p["w1"].astype(x.dtype), MODEL), p_in, out_model=True
        )
        if cfg.ffn_type == "swiglu":
            g = sparse_matmul_auto(
                x, fetch(p["w3"].astype(x.dtype), MODEL), p_in, out_model=True
            )
            h = jax.nn.silu(h) * g
        else:
            h = _act(cfg, h)
        return sparse_matmul_auto(h, fetch(p["w2"].astype(x.dtype), MODEL), p_out)
    h = x @ fetch(p["w1"].astype(x.dtype), None, MODEL)
    if cfg.ffn_type == "swiglu":
        h = jax.nn.silu(h) * (x @ fetch(p["w3"].astype(x.dtype), None, MODEL))
    else:
        h = _act(cfg, h)
    return h @ fetch(p["w2"].astype(x.dtype), MODEL, None)
