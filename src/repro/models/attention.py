"""Attention mixers: GQA (+RoPE, optional qk-norm) and MLA (DeepSeek-V2).

Cache layouts (per layer):
  gqa   {"k": (B, S_max, Kv, Dh), "v": (B, S_max, Kv, Dh)}
  mla   {"ckv": (B, S_max, kv_lora), "kr": (B, S_max, rope_dim)}
  cross {"k": (B, S_src, Kv, Dh), "v": ...}  (computed once at prefill)

Decode uses the *absorbed* MLA formulation (score/value contractions in the
compressed kv_lora space) so per-step cost is O(S * (kv_lora + rope)) per
head — the memory/bandwidth saving that motivates MLA.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.ctx import MODEL, fetch
from .config import ModelConfig
from .layers import dense_init, rmsnorm, rope

__all__ = ["gqa_init", "gqa_apply", "mla_init", "mla_apply", "cross_init", "cross_apply"]

NEG_INF = -1e30


def _sdpa(q, k, v, mask) -> jnp.ndarray:
    """q: (B,Sq,K,G,Dh) grouped; k,v: (B,Sk,K,Dh); mask: (B,1,1,Sq,Sk) or None."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out


def _sdpa_chunked(q, k, v, q_offset, causal: bool, chunk: int) -> jnp.ndarray:
    """Streaming-softmax (flash) attention: scan over key chunks with
    running (m, l, acc) — never materializes (Sq, Sk) scores.  Numerically
    identical to `_sdpa` (same f32 softmax accumulation).

    q: (B,Sq,K,G,D) at global positions q_offset+i; k/v: (B,Sk,K,D).
    """
    B, Sq, K, G, D = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(D)
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (Sk + pad) // chunk
    kc = k.reshape(B, nc, chunk, K, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, K, D).transpose(1, 0, 2, 3, 4)
    kpos = (jnp.arange(nc * chunk).reshape(nc, chunk))
    qpos = q_offset + jnp.arange(Sq)

    m0 = jnp.full((B, K, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        kci, vci, kpi = inp
        s = jnp.einsum("bqkgd,bskd->bkgqs", q, kci).astype(jnp.float32) * scale
        valid = (kpi < Sk)[None, :]
        if causal:
            valid = valid & (kpi[None, :] <= qpos[:, None])
        s = jnp.where(valid[None, None, None], s, -jnp.inf)
        m2 = jnp.maximum(m, s.max(axis=-1))
        # exp(-inf - -inf) guard: rows with no valid keys yet keep l=0
        p = jnp.exp(s - jnp.where(jnp.isinf(m2), 0.0, m2)[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        alpha = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - jnp.where(
            jnp.isinf(m2), 0.0, m2)))
        l2 = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), vci)
        acc2 = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
        return (m2, l2, acc2), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, kpos))
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.astype(q.dtype)


def causal_mask(sq: int, sk: int, offset) -> jnp.ndarray:
    """(1,1,1,Sq,Sk) boolean: query i (global pos offset+i) sees key j<=pos."""
    qpos = offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    return (kpos <= qpos)[None, None, None]


# ---------------------------------------------------------------------- #
# GQA
# ---------------------------------------------------------------------- #
def gqa_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, H, Kv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * Dh), dtype=dtype),
        "wk": dense_init(ks[1], (d, Kv * Dh), dtype=dtype),
        "wv": dense_init(ks[2], (d, Kv * Dh), dtype=dtype),
        "wo": dense_init(ks[3], (H * Dh, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["qn"] = jnp.ones((Dh,), dtype)
        p["kn"] = jnp.ones((Dh,), dtype)
    return p


def gqa_apply(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,  # (S,) or (B,S) global positions of x tokens
    cache: Optional[dict] = None,
    cache_pos=None,  # scalar write offset into cache (decode/prefill)
    causal: bool = True,
):
    B, S, d = x.shape
    H, Kv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Kv
    q = (x @ fetch(p["wq"].astype(x.dtype), None, MODEL)).reshape(B, S, H, Dh)
    k = (x @ fetch(p["wk"].astype(x.dtype), None, MODEL)).reshape(B, S, Kv, Dh)
    v = (x @ fetch(p["wv"].astype(x.dtype), None, MODEL)).reshape(B, S, Kv, Dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["qn"], cfg.norm_eps)
        k = rmsnorm(k, p["kn"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_pos, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_pos, 1)
        new_cache = {"k": ck, "v": cv}
        k_all, v_all = ck.astype(x.dtype), cv.astype(x.dtype)
        q_offset = cache_pos
    else:
        k_all, v_all = k, v
        q_offset = 0

    qg = q.reshape(B, S, Kv, G, Dh)
    chunk = cfg.attn_chunk
    if chunk and S > 1 and k_all.shape[1] >= 2 * chunk:
        # flash-style streaming softmax: no (Sq, Sk) materialization
        out = _sdpa_chunked(qg, k_all, v_all, q_offset, causal, chunk)
    else:
        sk = k_all.shape[1]
        mask = causal_mask(S, sk, q_offset) if causal else None
        out = _sdpa(qg, k_all, v_all, mask)
    out = out.reshape(B, S, H * Dh)
    return out @ fetch(p["wo"].astype(x.dtype), MODEL, None), new_cache


# ---------------------------------------------------------------------- #
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------- #
def mla_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 6)
    return {
        "wdq": dense_init(ks[0], (d, m.q_lora_rank), dtype=dtype),
        "qln": jnp.ones((m.q_lora_rank,), dtype),
        "wuq": dense_init(ks[1], (m.q_lora_rank, H * qk), dtype=dtype),
        "wdkv": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim), dtype=dtype),
        "kvln": jnp.ones((m.kv_lora_rank,), dtype),
        "wukv": dense_init(
            ks[3], (m.kv_lora_rank, H * (m.qk_nope_dim + m.v_head_dim)), dtype=dtype
        ),
        "wo": dense_init(ks[4], (H * m.v_head_dim, d), dtype=dtype),
    }


def mla_apply(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    cache: Optional[dict] = None,
    cache_pos=None,
    causal: bool = True,
    absorb: Optional[bool] = None,
):
    """MLA forward.  ``absorb=None`` auto: absorbed path for single-token
    decode, materialized path for train/prefill."""
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim
    if absorb is None:
        absorb = cache is not None and S == 1

    cq = rmsnorm(x @ fetch(p["wdq"].astype(x.dtype), None, None), p["qln"], cfg.norm_eps)
    q = (cq @ fetch(p["wuq"].astype(x.dtype), None, MODEL)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv_full = x @ fetch(p["wdkv"].astype(x.dtype), None, None)  # (B,S,kv_lora+dr)
    ckv = rmsnorm(ckv_full[..., : m.kv_lora_rank], p["kvln"], cfg.norm_eps)
    k_rope = rope(ckv_full[..., None, m.kv_lora_rank :], positions, cfg.rope_theta)[
        :, :, 0
    ]  # (B,S,dr) shared across heads

    new_cache = None
    if cache is not None:
        cckv = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_pos, 1
        )
        ckr = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], k_rope.astype(cache["kr"].dtype), cache_pos, 1
        )
        new_cache = {"ckv": cckv, "kr": ckr}
        ckv_all, kr_all = cckv.astype(x.dtype), ckr.astype(x.dtype)
        sk = ckv_all.shape[1]
        mask = causal_mask(S, sk, cache_pos)
    else:
        ckv_all, kr_all = ckv, k_rope
        sk = S
        mask = causal_mask(S, S, 0) if causal else None

    wukv = fetch(p["wukv"].astype(x.dtype), None, MODEL).reshape(m.kv_lora_rank, H, dn + dv)
    wuk, wuv = wukv[..., :dn], wukv[..., dn:]  # (kv_lora, H, dn/dv)
    scale = 1.0 / np.sqrt(dn + dr)

    if absorb:
        # score = (q_nope @ wuk^T) . ckv + q_rope . k_rope  — MQA-like in
        # compressed space; per-step cost O(S*(kv_lora+dr)) per head.
        q_c = jnp.einsum("bqhd,chd->bqhc", q_nope, wuk)  # (B,S,H,kv_lora)
        s1 = jnp.einsum("bqhc,bsc->bhqs", q_c, ckv_all)
        s2 = jnp.einsum("bqhd,bsd->bhqs", q_rope, kr_all)
        scores = (s1 + s2).astype(jnp.float32) * scale
        if mask is not None:
            scores = jnp.where(mask[:, 0], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o_c = jnp.einsum("bhqs,bsc->bqhc", probs, ckv_all)  # compressed values
        out = jnp.einsum("bqhc,chd->bqhd", o_c, wuv).reshape(B, S, H * dv)
    else:
        kv = jnp.einsum("bsc,chd->bshd", ckv_all, wukv)  # materialized k_nope|v
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None], (B, sk, H, dr))], axis=-1
        )
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        scores = jnp.einsum("bqhd,bshd->bhqs", qf, k).astype(jnp.float32) * scale
        if mask is not None:
            scores = jnp.where(mask[:, 0], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v).reshape(B, S, H * dv)
    return out @ fetch(p["wo"].astype(x.dtype), MODEL, None), new_cache


# ---------------------------------------------------------------------- #
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------- #
def cross_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, H, Kv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, H * Dh), dtype=dtype),
        "wk": dense_init(ks[1], (d, Kv * Dh), dtype=dtype),
        "wv": dense_init(ks[2], (d, Kv * Dh), dtype=dtype),
        "wo": dense_init(ks[3], (H * Dh, d), dtype=dtype),
    }


def cross_kv(p: dict, enc_out: jnp.ndarray, cfg: ModelConfig):
    B, Sk, _ = enc_out.shape
    Kv, Dh = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ fetch(p["wk"].astype(enc_out.dtype), None, MODEL)).reshape(B, Sk, Kv, Dh)
    v = (enc_out @ fetch(p["wv"].astype(enc_out.dtype), None, MODEL)).reshape(B, Sk, Kv, Dh)
    return {"k": k, "v": v}


def cross_apply(p: dict, x: jnp.ndarray, kv: dict, cfg: ModelConfig):
    B, S, d = x.shape
    H, Kv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Kv
    q = (x @ fetch(p["wq"].astype(x.dtype), None, MODEL)).reshape(B, S, Kv, G, Dh)
    out = _sdpa(q, kv["k"].astype(x.dtype), kv["v"].astype(x.dtype), None)
    return out.reshape(B, S, H * Dh) @ fetch(p["wo"].astype(x.dtype), MODEL, None)
