"""Fault-tolerant checkpointing.

Guarantees:
  * **atomicity** — writes go to ``<dir>/tmp.<step>`` and are renamed to
    ``<dir>/step_<n>`` only after fsync; a crash mid-save never corrupts
    the latest checkpoint,
  * **asynchrony** — ``save_async`` snapshots device arrays to host then
    writes on a background thread; training continues,
  * **elasticity** — the manifest records leaf paths/shapes/dtypes and the
    logical PartitionSpec; ``restore`` re-shards onto ANY mesh (different
    device count / topology), which is the elastic-scaling and
    failed-node-replacement path,
  * **retention** — keep_last_k garbage collection.

On a real multi-host pod each host writes only the shards it owns
(addressable_shards); in this single-process container that degenerates to
full arrays, same code path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "CheckpointManager"]

_NATIVE_DTYPES = {
    "float16", "float32", "float64", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool",
}


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    return names, [l for _, l in flat], treedef


def save_checkpoint(directory: str, step: int, tree, extra: Optional[dict] = None):
    """Synchronous atomic save."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names, leaves, _ = _leaf_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        raw = arr.dtype.name not in _NATIVE_DTYPES
        if raw:  # bf16/fp8 etc: store raw bytes, keep logical dtype in meta
            np.save(os.path.join(tmp, fname),
                    np.ascontiguousarray(arr).view(np.uint8))
        else:
            np.save(os.path.join(tmp, fname), arr)
        spec = ""
        shd = getattr(leaf, "sharding", None)
        if shd is not None and hasattr(shd, "spec"):
            spec = str(shd.spec)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype), "raw": raw, "spec": spec}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(directory) if d.startswith("step_")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional pytree (or flat list) of NamedSharding for the
    *current* mesh — arrays are re-sharded on load (elastic restore).
    Returns (tree, step, extra).
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves, treedef = _leaf_paths(tree_like)
    by_name = {l["name"]: l for l in manifest["leaves"]}
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
    out = []
    for i, (name, like) in enumerate(zip(names, leaves)):
        meta = by_name[name]
        arr = np.load(os.path.join(d, meta["file"]))
        if meta.get("raw"):
            import ml_dtypes  # noqa: F401  (registers bf16/fp8 dtypes)

            dt = np.dtype(meta["dtype"])
            arr = arr.reshape(-1).view(dt).reshape(meta["shape"])
        elif hasattr(like, "dtype"):
            arr = arr.astype(like.dtype)
        if shard_flat is not None:
            arr = jax.device_put(arr, shard_flat[i])
        out.append(arr)
    return treedef.unflatten(out), step, manifest.get("extra", {})


class CheckpointManager:
    """Async, retained, atomic checkpoints + elastic restore."""

    def __init__(self, directory: str, keep_last_k: int = 3):
        self.directory = directory
        self.keep = keep_last_k
        self._thread: Optional[threading.Thread] = None
        self.save_times: list[float] = []

    def save_async(self, step: int, tree, extra: Optional[dict] = None):
        self.wait()  # one in-flight save
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)

        def work():
            t0 = time.perf_counter()
            save_checkpoint(self.directory, step, host_tree, extra)
            self._gc()
            self.save_times.append(time.perf_counter() - t0)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree, extra: Optional[dict] = None):
        self.wait()
        save_checkpoint(self.directory, step, tree, extra)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, tree_like, step=None, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, tree_like, step, shardings)

    def latest_step(self):
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_")
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))
