"""AdamW with global-norm clipping and optional low-precision moments.

Pure pytree functions (no optax dependency).  Moment dtype is configurable
(``state_dtype='bfloat16'`` halves optimizer HBM — the knob that lets 398B
Jamba train on a single 256-chip pod; see EXPERIMENTS.md).  Because params
and moments share the params' sharding, ZeRO-style optimizer-state
sharding falls out of the FSDP param specs for free.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4  # peak; callers may pass a schedule value per step
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Optional[str] = None  # None = follow param dtype


def adamw_init(params, cfg: AdamWConfig):
    dt = lambda p: jnp.dtype(cfg.state_dtype) if cfg.state_dtype else p.dtype
    zeros = lambda p: jnp.zeros(p.shape, dt(p))
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(params, grads, state, cfg: AdamWConfig, lr=None):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    lr = cfg.lr if lr is None else lr
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        step = (mu32 / c1) / (jnp.sqrt(nu32 / c2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/scalars exempt)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {
        "mu": tdef.unflatten([o[1] for o in out]),
        "nu": tdef.unflatten([o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gnorm}
