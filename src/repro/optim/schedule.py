"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, peak_lr: float, warmup: int, total: int, min_ratio=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)
