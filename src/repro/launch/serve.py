"""Serving launcher: single-batch generate or continuous batching.

Single-batch (the legacy path — one prefill, lockstep decode)::

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --batch 4 --prompt-len 32 --gen 32

Continuous batching (request-level scheduler over the paged KV cache,
mixed prompt/generation lengths, admission + eviction mid-decode)::

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --continuous --requests 12 --max-batch 4 --gen 32
"""
from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--continuous", action="store_true",
                    help="request-level continuous batching (paged KV cache)")
    ap.add_argument("--requests", type=int, default=8,
                    help="[--continuous] number of mixed-length requests")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="[--continuous] decode lanes")
    ap.add_argument("--page-size", type=int, default=16,
                    help="[--continuous] KV page size (token positions)")
    ap.add_argument("--policy", default="fcfs",
                    choices=("fcfs", "warm_first"),
                    help="[--continuous] admission policy")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="[--continuous] share page-aligned prompt-prefix "
                         "pages across requests (copy-on-write)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="[--continuous] prefill long prompts in chunks "
                         "interleaved with decode steps")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="[--chunked-prefill] tokens per prefill chunk "
                         "(default 2*page_size)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="[--continuous] prepend this many identical tokens "
                         "to every prompt (demo workload for --prefix-sharing)")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import numpy as np
    import jax
    import jax.numpy as jnp  # noqa: F401  (kept for interactive use)

    from ..configs import get_config
    from ..models.transformer import init_params
    from ..serve.engine import ServeEngine

    cfg = get_config(args.arch, reduced=args.reduced)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(
        cfg, params,
        max_len=args.shared_prefix + args.prompt_len + args.gen,
    )
    if engine.warmup_stats["plans_staged"]:
        print(f"staged {engine.warmup_stats['plans_staged']} sparse plans "
              "(cold cache); restart to serve warm")

    if args.continuous:
        rng = np.random.default_rng(1)
        shared = rng.integers(
            0, cfg.vocab_size, size=(args.shared_prefix,)
        ).astype(np.int32)
        reqs = []
        for i in range(args.requests):
            P = int(rng.integers(max(args.prompt_len // 4, 1),
                                 args.prompt_len + 1))
            G = int(rng.integers(max(args.gen // 4, 1), args.gen + 1))
            suffix = rng.integers(0, cfg.vocab_size, size=(P,)).astype(
                np.int32)
            reqs.append({
                "prompt": np.concatenate([shared, suffix]),
                "max_new_tokens": G,
                "temperature": args.temperature,
                "rng": jax.random.PRNGKey(i),
                "rid": f"req{i}",
            })
        t0 = time.perf_counter()
        results, sched = engine.serve(
            reqs, page_size=args.page_size, max_batch=args.max_batch,
            policy=args.policy,
            prefix_sharing=args.prefix_sharing,
            chunked_prefill=args.chunked_prefill,
            prefill_chunk=args.prefill_chunk,
        )
        dt = time.perf_counter() - t0
        s = sched.stats
        print(f"served {s['finished']} requests in {dt:.2f}s: "
              f"{s['steps']} steps, {s['decode_tokens']} decode tokens "
              f"({s['decode_tokens'] / max(dt, 1e-9):.1f} tok/s), "
              f"{s['evictions']} evictions, {s['resumes']} resumes")
        if args.prefix_sharing or args.chunked_prefill:
            print(f"prefix sharing: {s['prefix_hits']} hits, "
                  f"{s['pages_shared']} pages shared, "
                  f"{s['cow_copies']} COW copies; "
                  f"prefill {s['prefill_tokens']} tokens "
                  f"in {s['prefill_chunks']} chunks")
        first = results["req0"]
        print("first request:", first["tokens"][: first["prompt_len"] + 8].tolist())
        return

    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.perf_counter()
    out, stats = engine.generate(
        prompts, max_new_tokens=args.gen, temperature=args.temperature
    )
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"(prefill {stats['prefill_s']:.2f}s, "
          f"{stats['tokens_per_s']:.1f} tok/s decode)")
    print("first sequence:", out[0, : args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
