"""Serving launcher: batched prefill + decode with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models.transformer import decode_step, init_cache, init_params, prefill
    from ..serve.engine import ServeEngine

    cfg = get_config(args.arch, reduced=args.reduced)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=args.prompt_len + args.gen)

    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.perf_counter()
    out, stats = engine.generate(
        prompts, max_new_tokens=args.gen, temperature=args.temperature
    )
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"(prefill {stats['prefill_s']:.2f}s, "
          f"{stats['tokens_per_s']:.1f} tok/s decode)")
    print("first sequence:", out[0, : args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
