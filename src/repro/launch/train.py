"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real pod this binary runs per host (jax.distributed.initialize is
called when JAX_COORDINATOR is set) with the production mesh; in this
container it runs the same code on the local mesh.
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--sable", action="store_true",
                    help="enable SABLE block-sparse FFN (llama3-8b)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (testing)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    if os.environ.get("JAX_COORDINATOR"):  # real multi-host pod
        jax.distributed.initialize()

    import dataclasses

    from ..configs import get_config
    from ..configs.shapes import Shape
    from ..data.pipeline import make_dataset
    from ..distributed.sharding import (
        ParallelConfig, batch_specs, make_shardings, param_specs,
    )
    from ..models.transformer import init_params
    from ..optim.adamw import AdamWConfig, adamw_init
    from ..optim.schedule import cosine_schedule
    from ..train.loop import TrainLoop
    from ..train.step import make_train_step
    from .mesh import make_local_mesh

    if args.sable:
        from ..configs import llama3_8b

        cfg = llama3_8b.reduced_sable() if args.reduced else llama3_8b.full_sable()
    else:
        cfg = get_config(args.arch, reduced=args.reduced)
    shape = Shape("cli", args.seq, args.batch, "train")
    mesh = make_local_mesh(("data", "model"))
    pc = ParallelConfig()
    opt_cfg = AdamWConfig(lr=args.lr)
    sched = lambda s: cosine_schedule(s, args.lr, warmup=20, total=args.steps)

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw_init(params, opt_cfg)
    pshard = make_shardings(mesh, pc, param_specs(cfg, params), params)
    oshard = {"mu": pshard, "nu": pshard, "count": NamedSharding(mesh, P())}
    params = jax.device_put(params, pshard)
    opt_state = jax.device_put(opt_state, oshard)

    ds = make_dataset(cfg, shape)
    example = next(iter(ds))
    bshard = make_shardings(mesh, pc, batch_specs(cfg, example), example)

    step = make_train_step(cfg, opt_cfg, pc, schedule=sched)
    jstep = jax.jit(
        step,
        in_shardings=(pshard, oshard, bshard, NamedSharding(mesh, P())),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1),
    )

    def wrapped(params, opt, batch, i):
        batch = jax.device_put(batch, bshard)
        return jstep(params, opt, batch, jnp.int32(i))

    loop = TrainLoop(wrapped, ds, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every)
    if args.resume:
        params, opt_state, resumed = loop.maybe_restore(params, opt_state)
        print(f"resumed={resumed} at step {loop.step}")
    params, opt_state, metrics = loop.run(
        params, opt_state, args.steps, log_every=args.log_every
    )
    print(f"final loss {float(metrics['loss']):.4f} @ step {loop.step}")


if __name__ == "__main__":
    main()
