"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Run:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out experiments/dryrun

The first two lines MUST set XLA_FLAGS before any jax import: the dry-run
(and only the dry-run) builds the 512-device production mesh on host
placeholder devices.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCH_IDS, SHAPES, get_config, shape_applicable  # noqa: E402
from ..configs.shapes import src_len  # noqa: E402
from ..distributed.sharding import (  # noqa: E402
    ParallelConfig,
    batch_specs,
    cache_specs,
    make_shardings,
    param_specs,
)
from ..models.config import ModelConfig, param_count  # noqa: E402
from ..models.transformer import decode_step, encode, prefill  # noqa: E402
from ..optim.adamw import AdamWConfig  # noqa: E402
from ..train.step import make_train_step  # noqa: E402
from . import hlo_stats  # noqa: E402
from .mesh import HW, make_production_mesh  # noqa: E402
from .specs import abstract_cache, abstract_opt, abstract_params, input_specs  # noqa: E402

OPT = AdamWConfig(state_dtype="bfloat16")


def _replicated(mesh):
    return NamedSharding(mesh, P())


def build_cell(cfg: ModelConfig, shape, mesh, pc: ParallelConfig):
    """Returns (jitted_fn, example_args, in_shardings_tree) for the cell."""
    model_size = mesh.shape[pc.tensor_axis]
    if shape.kind != "train" and pc.fsdp:
        # Serving: FSDP would re-gather weight shards every decode step
        # (§Perf iteration 3: mamba2 decode was collective-bound on weight
        # all-gathers).  Replicate over dp when the TP-sharded params fit
        # comfortably (< 8 GB/device), else keep ZeRO sharding.
        per_dev = 4 * param_count(cfg) / model_size
        if per_dev < 8e9:
            pc = dataclasses.replace(pc, fsdp=False)
    params = abstract_params(cfg)
    pshard = make_shardings(mesh, pc, param_specs(cfg, params), params)
    ins = input_specs(cfg, shape)
    rep = _replicated(mesh)

    if shape.kind == "train":
        opt = abstract_opt(cfg, OPT)
        oshard = {
            "mu": pshard,
            "nu": pshard,
            "count": rep,
        }
        batch = {k: v for k, v in ins.items()}
        bshard = make_shardings(mesh, pc, batch_specs(cfg, batch), batch)
        step_fn = make_train_step(cfg, OPT, pc)
        jitted = jax.jit(
            step_fn,
            in_shardings=(pshard, oshard, bshard, rep),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        args = (params, opt, batch, jax.ShapeDtypeStruct((), jnp.int32))
        return jitted, args

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        enc_len = src_len(cfg, shape) if cfg.is_encdec else 0
        cache = abstract_cache(cfg, B, S, enc_len)
        cshard = make_shardings(
            mesh, pc, cache_specs(cfg, cache, pc, model_size), cache
        )
        bshard = make_shardings(mesh, pc, batch_specs(cfg, ins), ins)

        if cfg.is_encdec:

            def prefill_fn(params, tokens, src_embeds, cache):
                enc_out = encode(params, cfg, src_embeds)
                return prefill(params, cfg, tokens, cache, enc_out=enc_out)

            jitted = jax.jit(
                prefill_fn,
                in_shardings=(pshard, bshard["tokens"], bshard["src_embeds"], cshard),
                out_shardings=(None, cshard),
                donate_argnums=(3,),
            )
            args = (params, ins["tokens"], ins["src_embeds"], cache)
        else:

            def prefill_fn(params, tokens, cache):
                return prefill(params, cfg, tokens, cache)

            jitted = jax.jit(
                prefill_fn,
                in_shardings=(pshard, bshard["tokens"], cshard),
                out_shardings=(None, cshard),
                donate_argnums=(2,),
            )
            args = (params, ins["tokens"], cache)
        return jitted, args

    # decode
    B, S = shape.global_batch, shape.seq_len
    enc_len = src_len(cfg, shape) if cfg.is_encdec else 0
    cache = abstract_cache(cfg, B, S, enc_len)
    cshard = make_shardings(mesh, pc, cache_specs(cfg, cache, pc, model_size), cache)
    tok_shard = make_shardings(
        mesh, pc, batch_specs(cfg, {"token": ins["token"]}),
        {"token": ins["token"]},
    )["token"]

    def decode_fn(params, token, cache, pos):
        return decode_step(params, cfg, token, cache, pos)

    jitted = jax.jit(
        decode_fn,
        in_shardings=(pshard, tok_shard, cshard, rep),
        out_shardings=(None, cshard),
        donate_argnums=(2,),
    )
    args = (params, ins["token"], cache, ins["pos"])
    return jitted, args


def analyze(compiled, cfg, shape, mesh) -> dict:
    n_dev = mesh.size
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    memd = {}
    if mem is not None:
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            memd[k] = int(getattr(mem, k, 0))
    hlo = compiled.as_text()
    stats = hlo_stats.analyze_hlo(hlo)  # trip-count-aware (see hlo_stats)
    flops = stats["flops"]
    bytes_acc = stats["hbm_bytes_est"]
    coll = stats["collectives"]

    # roofline terms (per device; the module is the SPMD per-device program)
    t_compute = flops / HW["peak_bf16_flops"]
    t_memory = bytes_acc / HW["hbm_bw"]
    # bf16 adjustment: the host backend upcasts bf16 dots to f32, so their
    # partial-sum collectives appear at 2x the bytes a TPU build moves.
    wire = coll["total_wire_bytes"]
    if jnp.dtype(cfg.compute_dtype) == jnp.bfloat16:
        wire -= 0.5 * coll.get("total_f32_wire_bytes", 0.0)
    dcn = coll["total_dcn_wire_bytes"]
    t_coll = max(wire - dcn, 0.0) / HW["ici_bw"] + dcn / HW["dcn_bw"]
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]

    n_total = param_count(cfg)
    n_active = param_count(cfg, active=True)
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind in ("train", "prefill") else 1
    )
    model_flops = (
        6.0 * n_active * tokens
        if shape.kind == "train"
        else 2.0 * n_active * tokens
    )
    return {
        "devices": n_dev,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "cost_analysis_flops": float(cost.get("flops", 0.0)),
        "cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": memd,
        "roofline": {
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "dominant": dominant,
        },
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / n_dev,
        "useful_flops_ratio": (model_flops / n_dev) / flops if flops else 0.0,
        "params_total": n_total,
        "params_active": n_active,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             pc: ParallelConfig = ParallelConfig(), cfg: ModelConfig = None,
             verbose: bool = True) -> dict:
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    report = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "parallel": dataclasses.asdict(pc),
    }
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        report.update(status="skipped", reason=reason)
        return report
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        from ..distributed.ctx import activation_sharding

        t0 = time.perf_counter()
        with activation_sharding(mesh, pc):
            jitted, args = build_cell(cfg, shape, mesh, pc)
            lowered = jitted.lower(*args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        report.update(
            status="ok",
            lower_time_s=round(t1 - t0, 2),
            compile_time_s=round(t2 - t1, 2),
            **analyze(compiled, cfg, shape, mesh),
        )
        if verbose:
            mem = report["memory"]
            args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
            tmp_gb = mem.get("temp_size_in_bytes", 0) / 1e9
            r = report["roofline"]
            print(
                f"[ok] {arch} x {shape_name} x {mesh_name}: "
                f"args {args_gb:.2f} GB/dev, temp {tmp_gb:.2f} GB/dev, "
                f"compute {r['t_compute_s']*1e3:.2f} ms, "
                f"memory {r['t_memory_s']*1e3:.2f} ms, "
                f"collective {r['t_collective_s']*1e3:.2f} ms "
                f"-> {r['dominant']}-bound "
                f"(lower {report['lower_time_s']}s, "
                f"compile {report['compile_time_s']}s)",
                flush=True,
            )
    except Exception as e:  # a failure here is a bug in the system
        report.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[ERR] {arch} x {shape_name} x {mesh_name}: {e}", flush=True)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-fsdp", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod
    ]
    pc = ParallelConfig(fsdp=not args.no_fsdp)

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in pods:
                rep = run_cell(arch, shape_name, mp, pc)
                tag = f"{arch}_{shape_name}_{rep['mesh']}".replace(".", "_")
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rep, f, indent=1)
                n_ok += rep["status"] == "ok"
                n_skip += rep["status"] == "skipped"
                n_err += rep["status"] == "error"
    print(f"dry-run: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
