"""Post-SPMD HLO analysis: FLOPs, memory-traffic estimate, collective bytes.

Why not ``compiled.cost_analysis()``: on the host backend it counts
``while`` (lax.scan) bodies exactly ONCE, so any scanned-layer model is
undercounted by the layer count; and it has no collective accounting.  We
therefore walk the compiled per-device HLO text ourselves:

  * computations are parsed into blocks; call edges (while/fusion/call/
    conditional/to_apply) form a DAG,
  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}`` —
    bodies are multiplied by their trip count,
  * dot/convolution FLOPs are computed exactly from shapes + dnums
    (elementwise FLOPs are ignored — the MXU roofline term is matmul
    FLOPs; VPU work is folded into the memory term),
  * memory traffic is estimated as every op's OUTPUT bytes (each
    intermediate written once; fusions count their root only) — operands
    are other ops' outputs, so reads are counted at their producer; this
    approximates a perfectly-fused TPU schedule's HBM writes and is
    reported alongside cost_analysis' (CPU-flavored) bytes,
  * collectives get ring-model wire factors:
      all-reduce 2(n-1)/n, all-gather/reduce-scatter/all-to-all (n-1)/n,
      collective-permute 1.

Bytes are per-device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["HloCost", "analyze_hlo", "collective_stats", "op_census",
           "parse_shape_bytes"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list(sig: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",") if d]
        out.append((dt, shape))
    return out


def parse_shape_bytes(sig: str) -> int:
    total = 0
    for dt, shape in _shape_list(sig):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    if "source_target_pairs" in line:
        return 2
    return 1


def _group_stride(line: str) -> int:
    """Max participant stride within a replica group (>=256 => crosses the
    pod/DCN boundary on the (2,16,16) production mesh)."""
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        if len(ids) >= 2:
            return max(abs(b - a) for a, b in zip(ids, ids[1:]))
        return 0
    # iota form: [G,n]<=[d0,d1,...]T(p0,p1,...)
    m = re.search(r"replica_groups=\[\d+,\d+\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
                  line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        perm = ([int(x) for x in m.group(2).split(",")]
                if m.group(2) else list(range(len(dims))))
        # stride between consecutive in-group elements = stride of the
        # last transposed axis in the original iota layout
        last_axis = perm[-1]
        stride = 1
        for d in dims[last_axis + 1:]:
            stride *= d
        return stride
    m = re.search(r"source_target_pairs=\{\{(\d+),(\d+)\}", line)
    if m:
        return abs(int(m.group(2)) - int(m.group(1)))
    return 0


def _wire_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind == "collective-permute":
        return 1.0
    return (n - 1) / n


class _Op:
    __slots__ = ("name", "out_sig", "opcode", "line", "calls", "trip")

    def __init__(self, name, out_sig, opcode, line):
        self.name = name
        self.out_sig = out_sig
        self.opcode = opcode
        self.line = line
        self.calls: list[tuple[str, float]] = []  # (computation, multiplier)
        self.trip = 1


_OP_RE = re.compile(
    r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[\w\[\],\s{}/#]*?\)?)\s+([\w\-]+)\("
)
_CALL_ATTRS = (
    ("body=", 1),
    ("condition=", 1),
    ("calls=", 1),
    ("to_apply=", 1),
)
_NAME_RE = re.compile(r"[%]?([\w.\-]+)")


def _parse_computations(hlo_text: str) -> tuple[dict, str]:
    comps: dict[str, list[_Op]] = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        line = re.sub(r"/\*[^*]*\*/", "", raw.rstrip())  # strip /*index=N*/
        # computation header: "%name (params...) -> type {"; parameter
        # signatures may contain nested parens (tuple types), so match the
        # name + trailing "{" and the absence of "=" before the paren.
        if (
            line.endswith("{")
            and "->" in line
            and "=" not in line.split("(", 1)[0]
        ):
            header = re.match(r"\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if header:
                cur = header.group(2)
                comps[cur] = []
                if header.group(1):
                    entry = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        op = _Op(m.group(1), m.group(2), m.group(3), line)
        # call edges
        for attr, mult in _CALL_ATTRS:
            idx = 0
            while True:
                j = line.find(attr, idx)
                if j < 0:
                    break
                nm = _NAME_RE.match(line[j + len(attr):])
                if nm:
                    op.calls.append((nm.group(1), mult))
                idx = j + len(attr)
        bm = re.search(r"branch_computations=\{([^}]*)\}", line)
        if bm:
            for part in bm.group(1).split(","):
                nm = _NAME_RE.match(part.strip())
                if nm:
                    op.calls.append((nm.group(1), 1))
        tm = re.search(r'known_trip_count[^0-9]*(\d+)', line)
        if tm and op.opcode == "while":
            op.trip = int(tm.group(1))
        comps[cur].append(op)
    return comps, entry


_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")


def _operand_names(op: _Op) -> list:
    """Operand names of the op.  Depending on the XLA version, compiled HLO
    prints operands either as bare names (``dot(%a, %b)``) or with full
    shapes (``dot(f32[64,128]{1,0} %a, ...)``) — shape dims and layouts
    contain commas, so splitting must track ``[]``/``{}`` nesting too."""
    after = op.line.split(op.opcode + "(", 1)
    if len(after) < 2:
        return []
    depth, nest, out, cur = 1, 0, [], []
    for ch in after[1]:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        elif ch in "[{":
            nest += 1
        elif ch in "]}":
            nest -= 1
        if ch == "," and depth == 1 and nest == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return [re.sub(r"^%", "", x.split(" ")[-1]) for x in out if x]


def _dot_flops(op: _Op, sigmap: dict) -> float:
    # output numel x 2 x prod(lhs contracting dims)
    shapes = _shape_list(op.out_sig)
    if not shapes:
        return 0.0
    out_numel = sum(_numel(s) for _, s in shapes)
    names = _operand_names(op)
    lhs = []
    if names and names[0] in sigmap:
        ls = _shape_list(sigmap[names[0]])
        lhs = ls[0][1] if ls else []
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if m and lhs:
        for d in m.group(1).split(","):
            if d:
                contract *= lhs[int(d)]
    return 2.0 * out_numel * contract


def _conv_flops(op: _Op, sigmap: dict) -> float:
    shapes = _shape_list(op.out_sig)
    if not shapes:
        return 0.0
    out_numel = sum(_numel(s) for _, s in shapes)
    names = _operand_names(op)
    kern = []
    if len(names) >= 2 and names[1] in sigmap:
        ks = _shape_list(sigmap[names[1]])
        kern = ks[0][1] if ks else []
    if not kern:
        return 0.0
    # per-output MACs = kernel numel / output features (depthwise => window)
    out_feat = kern[-1] if kern else 1
    per_out = _numel(kern) / max(out_feat, 1)
    return 2.0 * out_numel * per_out


class HloCost:
    def __init__(self):
        self.flops = 0.0
        self.out_bytes = 0.0  # memory-traffic estimate
        self.coll = defaultdict(lambda: {"count": 0.0, "bytes": 0.0,
                                         "wire_bytes": 0.0,
                                         "dcn_wire_bytes": 0.0,
                                         "max_group": 1})

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.out_bytes += other.out_bytes * mult
        for k, v in other.coll.items():
            s = self.coll[k]
            s["count"] += v["count"] * mult
            s["bytes"] += v["bytes"] * mult
            s["wire_bytes"] += v["wire_bytes"] * mult
            s["dcn_wire_bytes"] += v["dcn_wire_bytes"] * mult
            s["f32_wire_bytes"] = (
                s.get("f32_wire_bytes", 0.0)
                + v.get("f32_wire_bytes", 0.0) * mult
            )
            s["max_group"] = max(s["max_group"], v["max_group"])


_NO_TRAFFIC = {
    "get-tuple-element", "tuple", "parameter", "constant", "bitcast",
    "copy-done", "all-reduce-done", "all-gather-done", "copy-start",
    "after-all", "partition-id", "replica-id", "convert", "copy",
    # control-flow plumbing: the bodies' interior ops are counted instead
    "while", "conditional", "call",
}
_CONVERT_ONLY = {"parameter", "convert", "copy", "bitcast", "transpose",
                 "reshape"}


def _dus_update_bytes(callee_ops, op, sigmap_local) -> int:
    """For (fusions rooted in) dynamic-update-slice, the write is the
    UPDATE slice, not the full buffer (in-place DUS on TPU)."""
    for o in callee_ops:
        if o.opcode == "dynamic-update-slice":
            names = _operand_names(o)
            if len(names) >= 2 and names[1] in sigmap_local:
                return parse_shape_bytes(sigmap_local[names[1]])
    return -1


def analyze_hlo(hlo_text: str) -> dict:
    comps, entry = _parse_computations(hlo_text)
    memo: dict[str, HloCost] = {}
    sigmap: dict[str, str] = {}
    # fusions that only convert/copy/reshape exist because the CPU backend
    # computes bf16 in f32; a TPU build has no such traffic — skip them.
    convert_fusions = {
        name
        for name, ops in comps.items()
        if ops and all(o.opcode in _CONVERT_ONLY for o in ops)
    }
    for ops in comps.values():
        for op in ops:
            sigmap[op.name] = op.out_sig

    def cost_of(name: str, in_fusion: bool) -> HloCost:
        key = name + ("#f" if in_fusion else "")
        if key in memo:
            return memo[key]
        c = HloCost()
        memo[key] = c  # guards (acyclic anyway)
        for op in comps.get(name, []):
            oc = op.opcode
            base = oc.replace("-start", "")
            if oc == "dot":
                c.flops += _dot_flops(op, sigmap)
            elif oc == "convolution":
                c.flops += _conv_flops(op, sigmap)
            if base in _COLLECTIVES:
                b = parse_shape_bytes(op.out_sig)
                n = _group_size(op.line)
                wire = b * _wire_factor(base, n)
                s = c.coll[base]
                s["count"] += 1
                s["bytes"] += b
                s["wire_bytes"] += wire
                if _group_stride(op.line) >= 256:
                    s["dcn_wire_bytes"] += wire
                if "f32[" in op.out_sig and "bf16[" not in op.out_sig:
                    # the host backend computes bf16 dots in f32, so
                    # partial-sum collectives appear as f32; a TPU build
                    # reduces in bf16 (see dryrun bf16-adjusted term)
                    s["f32_wire_bytes"] = s.get("f32_wire_bytes", 0.0) + wire
                s["max_group"] = max(s["max_group"], n)
            if not in_fusion and oc not in _NO_TRAFFIC:
                is_convert_fusion = oc == "fusion" and any(
                    callee in convert_fusions for callee, _ in op.calls
                )
                if not is_convert_fusion:
                    b = parse_shape_bytes(op.out_sig)
                    if oc == "dynamic-update-slice":
                        names = _operand_names(op)
                        if len(names) >= 2 and names[1] in sigmap:
                            b = min(b, parse_shape_bytes(sigmap[names[1]]))
                    elif oc == "fusion":
                        for callee, _ in op.calls:
                            ub = _dus_update_bytes(
                                comps.get(callee, []), op,
                                {o.name: o.out_sig
                                 for o in comps.get(callee, [])},
                            )
                            if ub >= 0:
                                b = min(b, ub)
                    c.out_bytes += b
            for callee, _ in op.calls:
                sub_fusion = in_fusion or (oc == "fusion")
                sub = cost_of(callee, sub_fusion)
                c.add(sub, mult=op.trip)
        return c

    total = cost_of(entry, False) if entry else HloCost()
    # parameters (weights, caches, batch) are read from HBM at least once
    # per step — decode's dominant traffic; writes are counted at producers
    for op in comps.get(entry, []):
        if op.opcode == "parameter":
            total.out_bytes += parse_shape_bytes(op.out_sig)
    coll = {k: dict(v) for k, v in total.coll.items()}
    coll["total_wire_bytes"] = sum(v["wire_bytes"] for v in total.coll.values())
    coll["total_dcn_wire_bytes"] = sum(
        v["dcn_wire_bytes"] for v in total.coll.values()
    )
    coll["total_f32_wire_bytes"] = sum(
        v.get("f32_wire_bytes", 0.0) for v in total.coll.values()
    )
    coll["total_bytes"] = sum(v["bytes"] for v in total.coll.values())
    return {
        "flops": total.flops,
        "hbm_bytes_est": total.out_bytes,
        "collectives": coll,
    }


def collective_stats(hlo_text: str) -> dict:
    """Trip-count-aware collective stats (see analyze_hlo)."""
    return analyze_hlo(hlo_text)["collectives"]


def top_collectives(hlo_text: str, k: int = 12) -> list:
    """Largest collective ops with their source op_name metadata — the
    'which line of model code caused this traffic' profiler view."""
    comps, entry = _parse_computations(hlo_text)
    # compute trip multiplier per computation via the call graph
    mult: dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    while order:
        name = order.pop()
        for op in comps.get(name, []):
            for callee, _ in op.calls:
                m = mult.get(name, 1.0) * op.trip
                mult[callee] = max(mult.get(callee, 0.0), m)
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    rows = []
    for cname, ops in comps.items():
        for op in ops:
            base = op.opcode.replace("-start", "")
            if base not in _COLLECTIVES or op.opcode.endswith("-done"):
                continue
            b = parse_shape_bytes(op.out_sig)
            n = _group_size(op.line)
            m = re.search(r'op_name="([^"]*)"', op.line)
            src = m.group(1) if m else "?"
            trips = mult.get(cname, 1.0)
            rows.append({
                "kind": base, "bytes": b, "trips": trips,
                "total_wire": b * trips * _wire_factor(base, n),
                "group": n, "sig": op.out_sig[:60], "src": src[-110:],
            })
    rows.sort(key=lambda r: -r["total_wire"])
    return rows[:k]


def top_traffic(hlo_text: str, k: int = 12) -> list:
    """Largest HBM-traffic ops (output bytes x trips), with source
    metadata — the memory-term profiler twin of top_collectives."""
    comps, entry = _parse_computations(hlo_text)
    mult: dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    while order:
        name = order.pop()
        for op in comps.get(name, []):
            for callee, _ in op.calls:
                m = mult.get(name, 1.0) * op.trip
                mult[callee] = max(mult.get(callee, 0.0), m)
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    convert_fusions = {
        name for name, ops in comps.items()
        if ops and all(o.opcode in _CONVERT_ONLY for o in ops)
    }
    rows = []
    for cname, ops in comps.items():
        if "fused" in cname or cname in convert_fusions:
            continue  # count fusion roots at their call site only
        for op in ops:
            if op.opcode in _NO_TRAFFIC:
                continue
            if op.opcode == "fusion" and any(
                c in convert_fusions for c, _ in op.calls
            ):
                continue
            b = parse_shape_bytes(op.out_sig)
            trips = mult.get(cname, 1.0)
            if b * trips < 1e6:
                continue
            m = re.search(r'op_name="([^"]*)"', op.line)
            rows.append({
                "opcode": op.opcode, "bytes": b, "trips": trips,
                "total": b * trips, "sig": op.out_sig[:48],
                "src": (m.group(1) if m else "?")[-100:],
            })
    rows.sort(key=lambda r: -r["total"])
    return rows[:k]


def op_census(hlo_text: str, top: int = 15) -> list:
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT )?[%\w.\-]+ = \S+ ([\w\-]+)\(", line)
        if m:
            counts[m.group(1)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
