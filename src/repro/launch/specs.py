"""Abstract input specs (ShapeDtypeStruct) per (arch config x input shape).

No device memory is ever allocated: parameters, optimizer state, caches and
batches are all eval_shape'd.  These feed ``jit(...).lower()`` in the
dry-run and define the public contract for train.py / serve.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.shapes import Shape, src_len
from ..models.config import ModelConfig
from ..models.transformer import init_cache, init_params
from ..optim.adamw import AdamWConfig, adamw_init

__all__ = ["abstract_params", "abstract_opt", "abstract_cache", "input_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


@functools.lru_cache(maxsize=64)
def _abstract_params_cached(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def abstract_params(cfg: ModelConfig):
    return _abstract_params_cached(cfg)


def abstract_opt(cfg: ModelConfig, opt_cfg: AdamWConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params)


def abstract_cache(cfg: ModelConfig, batch: int, s_max: int, enc_len: int = 0):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, s_max, enc_len=enc_len,
                           dtype=jnp.dtype(cfg.compute_dtype))
    )


def input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """Model inputs for this cell (excl. params/opt/cache).

    train    {"tokens": (B,S), "labels": (B,S)} [+ src_embeds]
    prefill  {"tokens": (B,S)} [+ src_embeds]
    decode   {"token": (B,1), "pos": scalar}
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        if cfg.is_encdec:
            out["src_embeds"] = _sds(
                (B, src_len(cfg, shape), cfg.frontend_dim), jnp.float32
            )
        return out
    if shape.kind == "prefill":
        out = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.is_encdec:
            out["src_embeds"] = _sds(
                (B, src_len(cfg, shape), cfg.frontend_dim), jnp.float32
            )
        return out
    if shape.kind == "decode":
        return {
            "token": _sds((B, 1), jnp.int32),
            "pos": _sds((), jnp.int32),
        }
    raise ValueError(shape.kind)
