"""Production mesh construction.

Meshes are built by FUNCTIONS (never at module import) so importing this
module cannot touch jax device state before the launcher sets XLA_FLAGS.

Production target: TPU v5e pods, 256 chips each.
  single-pod  (16, 16)    ("data", "model")
  multi-pod   (2, 16, 16) ("pod", "data", "model")  — 512 chips; the pod
              axis crosses the DCN boundary (slower links), which is why
              pipeline/pure-DP parallelism lives there.
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_local_mesh", "make_staging_mesh", "HW"]


# TPU v5e hardware constants used by the roofline analysis
HW = {
    "peak_bf16_flops": 197e12,  # per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link (~49 GB/s)
    "dcn_bw": 6.25e9,  # bytes/s per host cross-pod (50 Gbps)
    "hbm_bytes": 16e9,  # per chip
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_staging_mesh(
    num_shards: int | tuple | None = None,
    axis: str = "shards",
    *,
    model: int | None = None,
    model_axis: str = "model",
):
    """Mesh for sharded staged execution (``stage_spmv(..., mesh=)``).

    1-D (the PR-3 behaviour): ``make_staging_mesh(8)`` — a ``"shards"``
    axis over the first 8 devices.  2-D: ``make_staging_mesh(4, model=2)``
    or ``make_staging_mesh((4, 2))`` — a ``("shards", "model")`` mesh where
    the model axis column-partitions the dense SpMM operand (and composes
    with tensor-parallel layers; see docs/architecture.md).  On CPU, force
    multiple host devices first:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    from jax.sharding import Mesh

    if isinstance(num_shards, (tuple, list)):
        if model is not None:
            raise ValueError("pass either a (shards, model) tuple or model=")
        num_shards, model = (int(d) for d in num_shards)
    devs = jax.devices()
    if num_shards is not None:
        n = num_shards
    else:  # all devices by default; with model= given, shards fill the rest
        n = len(devs) if model is None else len(devs) // max(model, 1)
    if model is None:
        if n > len(devs):
            raise ValueError(
                f"asked for {n} shards but only {len(devs)} devices"
            )
        return Mesh(np.asarray(devs[:n]), (axis,))
    if n < 1 or n * model > len(devs):
        raise ValueError(
            f"asked for {n}x{model} mesh but only {len(devs)} devices"
        )
    grid = np.asarray(devs[: n * model]).reshape(n, model)
    return Mesh(grid, (axis, model_axis))


def make_local_mesh(axes=("data", "model"), shape=None):
    """Mesh over whatever devices exist (tests/examples)."""
    n = jax.device_count()
    if shape is None:
        if len(axes) == 1:
            shape = (n,)
        else:
            m = 1
            for f in (2, 4, 8):
                if n % f == 0 and f <= n:
                    m = f
            shape = (n // m, m) if len(axes) == 2 else (1, n // m, m)
    assert int(np.prod(shape)) == n, f"{shape} != {n} devices"
    return jax.make_mesh(shape, axes)
