"""Production mesh construction.

Meshes are built by FUNCTIONS (never at module import) so importing this
module cannot touch jax device state before the launcher sets XLA_FLAGS.

Production target: TPU v5e pods, 256 chips each.
  single-pod  (16, 16)    ("data", "model")
  multi-pod   (2, 16, 16) ("pod", "data", "model")  — 512 chips; the pod
              axis crosses the DCN boundary (slower links), which is why
              pipeline/pure-DP parallelism lives there.
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_local_mesh", "make_staging_mesh", "HW"]


# TPU v5e hardware constants used by the roofline analysis
HW = {
    "peak_bf16_flops": 197e12,  # per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link (~49 GB/s)
    "dcn_bw": 6.25e9,  # bytes/s per host cross-pod (50 Gbps)
    "hbm_bytes": 16e9,  # per chip
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_staging_mesh(num_shards: int | None = None, axis: str = "shards"):
    """1-D mesh for sharded staged execution (``stage_spmv(..., mesh=)``).

    Uses the first ``num_shards`` devices (all of them by default).  On CPU,
    force multiple host devices first:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    from jax.sharding import Mesh

    devs = jax.devices()
    n = num_shards if num_shards is not None else len(devs)
    if n > len(devs):
        raise ValueError(f"asked for {n} shards but only {len(devs)} devices")
    return Mesh(np.asarray(devs[:n]), (axis,))


def make_local_mesh(axes=("data", "model"), shape=None):
    """Mesh over whatever devices exist (tests/examples)."""
    n = jax.device_count()
    if shape is None:
        if len(axes) == 1:
            shape = (n,)
        else:
            m = 1
            for f in (2, 4, 8):
                if n % f == 0 and f <= n:
                    m = f
            shape = (n // m, m) if len(axes) == 2 else (1, n // m, m)
    assert int(np.prod(shape)) == n, f"{shape} != {n} devices"
    return jax.make_mesh(shape, axes)
