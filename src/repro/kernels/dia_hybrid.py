"""DIA-hybrid SpMV: dense diagonals + staged-VBR remainder.

Fukaya et al. ("Accelerating the SpMV kernel ... partially diagonal
structures", PAPERS.md) split a partially-diagonal matrix into its dense
diagonals — stored DIA-style, one contiguous vector per offset — and a
remainder in a general format.  The diagonal half of the product is then
scatter-free: for each offset ``d``, ``y += w_d * x[row + d]`` is a
gather, a multiply, and a sum over offsets — no ``at[].add`` congestion,
no block tables, and the access pattern the hardware likes most.

Here the split is *staging-time structure* (``core/inspect.py`` picks the
dense offsets; values never move the split), so it composes with the rest
of the stack unchanged:

  * the diagonal part is two gather tables built at staging time — one
    into the ORIGINAL VBR value array (sentinel +1 encoding, slot 0 = the
    absent-entry zero), one into ``x`` (offsets clipped at the edges;
    safe because the weight there is the sentinel zero);
  * the remainder (off-diagonal entries) is re-blocked under the original
    partitions restricted to the blocks that still have entries, and
    staged through the normal ``StagedKernel`` path — so the remainder
    enjoys grouped/bucketed codegen and the executable cache;
  * the whole thing is an ``fn(val, x)`` over the original value layout,
    interchangeable with every other backend in the autotune candidate
    list (label ``"dia_hybrid"``).

CPU/XLA is where this backend earns its keep today (the scatter-free
diagonal path beats grouped's gather+einsum+scatter on banded patterns);
on TPU the candidate simply competes in the same measured search.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from ..core import vbr as vbrlib
from ..core.inspect import coo_slots, detect_structure
from ..core.reblock import build_vbr_from_coo

__all__ = ["DiaHybridKernel", "stage_dia_hybrid", "clear_dia_cache"]


class DiaHybridKernel:
    """``fn(val, x) -> y`` — dense diagonals DIA-style, remainder staged.

    ``offsets`` (col - row) defaults to the detector's dense set.  Both
    halves read the ORIGINAL ``val`` array; all indirection is baked at
    staging time.
    """

    def __init__(
        self,
        vbr: vbrlib.VBR,
        offsets: Optional[Sequence[int]] = None,
        opts=None,
        remainder_backend: str = "grouped",
    ):
        import time

        import jax
        import jax.numpy as jnp

        from ..core import staging as staginglib

        t0 = time.perf_counter()
        self.kind = "spmv"
        self.backend = "dia_hybrid"
        self.opts = opts if opts is not None else staginglib.StagingOptions(
            backend="dia_hybrid"
        )
        self.structure_hash = vbrlib.structure_hash(vbr)
        m, k = vbr.shape
        if offsets is None:
            info = detect_structure(vbr)
            if not info.wants_dia:
                raise ValueError(
                    "dia_hybrid: structure is not partially diagonal "
                    f"(class={info.structure_class!r}, diagonal occupancy "
                    f"{info.diag_occupancy:.2f}); pass offsets= explicitly "
                    "to override"
                )
            offsets = info.dense_offsets
        self.offsets = tuple(int(d) for d in offsets)
        if not self.offsets:
            raise ValueError("dia_hybrid needs at least one dense diagonal")
        # every STORED slot (zeros included): gathers are structure and
        # must survive value updates into stored-zero slots
        rows, cols, vidx = coo_slots(vbr)
        d = cols - rows
        on = np.isin(d, np.asarray(self.offsets, dtype=np.int64))

        # diagonal gather tables: W[i, r] = val[gather-1] for offset i
        off_arr = np.asarray(self.offsets, dtype=np.int64)
        off_pos = {int(o): i for i, o in enumerate(off_arr)}
        G = np.zeros((len(off_arr), m), dtype=np.int64)
        di = np.asarray([off_pos[int(x)] for x in d[on]], dtype=np.int64)
        G[di, rows[on]] = vidx[on] + 1
        XI = np.clip(np.arange(m)[None, :] + off_arr[:, None], 0, k - 1)
        self.num_diagonals = len(off_arr)

        # remainder: off-diagonal entries under the original partitions
        # (restricted to blocks that still have entries)
        self._rem = None
        rem_gather = None
        if np.any(~on):
            rem_vbr, rem_gather = build_vbr_from_coo(
                rows[~on], cols[~on], vidx[~on],
                vbr.rpntr, vbr.cpntr, vbr.shape,
                val=np.asarray(vbr.val),
            )
            rem_opts = staginglib.StagingOptions(
                backend=remainder_backend,
                dtype=self.opts.dtype,
                interpret=self.opts.interpret,
            )
            self._rem = staginglib._cached("spmv", rem_vbr, rem_opts, None)
        self.remainder_nnz = int(np.count_nonzero(~on))

        gj = jnp.asarray(G)
        xij = jnp.asarray(XI)
        remg = None if rem_gather is None else jnp.asarray(rem_gather)
        rem = self._rem
        dtype_cast = self.opts.dtype

        def fn(val, x):
            if dtype_cast is not None:
                val, x = val.astype(dtype_cast), x.astype(dtype_cast)
            val1 = jnp.concatenate([jnp.zeros((1,), val.dtype), val])
            w = val1[gj].astype(x.dtype)  # (ndiag, m); 0 where absent
            y = (w * x[xij]).sum(axis=0)
            if rem is not None:
                y = y + rem(val1[remg], x)
            return y

        self._fn = jax.jit(fn)
        self.stage0_time = time.perf_counter() - t0
        self.compile_time = 0.0

    def __call__(self, val, x):
        return self._fn(val, x)

    @property
    def inspection_time(self) -> float:
        return self.stage0_time + self.compile_time


_KERNELS: dict[tuple, DiaHybridKernel] = {}


def stage_dia_hybrid(
    vbr: vbrlib.VBR,
    offsets: Optional[Sequence[int]] = None,
    opts=None,
) -> DiaHybridKernel:
    """Stage (or reuse) the DIA-hybrid SpMV kernel for one structure.

    ``offsets=None`` re-runs detection; a :class:`~.core.cache.TuningPlan`
    that chose this backend pins the offsets it was measured with in
    ``plan.meta['dia_offsets']`` so warm restarts stage byte-identically.
    """
    h = vbrlib.structure_hash(vbr)
    okey = None if opts is None else opts.key()
    key = (h, None if offsets is None else tuple(int(d) for d in offsets), okey)
    hit = _KERNELS.get(key)
    if hit is not None:
        return hit
    kern = DiaHybridKernel(vbr, offsets=offsets, opts=opts)
    _KERNELS[key] = kern
    return kern


def clear_dia_cache() -> None:
    _KERNELS.clear()
