"""Pallas TPU kernel: block-sparse SpMV over uniformized VBR tiles.

SpMV is VPU-bound (no MXU): each grid step multiplies one (tm, tk) tile by
a tk-slice of x and accumulates a tm-slice of y.  x and y are viewed as
(k_pad/tk, tk) and (m_pad/tm, tm) so all Pallas blocks are 2-D and
lane-aligned (tm, tk multiples of 128 on real hardware; anything in
interpret mode).  Same sorted-rows accumulate-in-VMEM schedule as SpMM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _kernel(row_ids, col_ids, tiles_ref, x_ref, y_ref, *, acc_dtype):
    b = pl.program_id(0)
    row = row_ids[b]
    prev_row = row_ids[jnp.maximum(b - 1, 0)]
    is_first = jnp.logical_or(b == 0, prev_row != row)

    @pl.when(is_first)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    tile = tiles_ref[0].astype(acc_dtype)  # (tm, tk)
    xv = x_ref[0].astype(acc_dtype)  # (tk,)
    acc = jnp.sum(tile * xv[None, :], axis=1)  # VPU reduce over lanes
    y_ref[0, :] += acc.astype(y_ref.dtype)


def bsr_spmv_pallas(
    tiles: jax.Array,  # (nb, tm, tk)
    row_ids: jax.Array,  # (nb,) int32, sorted
    col_ids: jax.Array,  # (nb,) int32
    x: jax.Array,  # (k_pad,)
    *,
    m_pad: int,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jax.Array:
    nb, tm, tk = tiles.shape
    (k_pad,) = x.shape
    assert k_pad % tk == 0 and m_pad % tm == 0
    x2 = x.reshape(k_pad // tk, tk)

    kernel = functools.partial(_kernel, acc_dtype=acc_dtype)
    in_specs = [
        pl.BlockSpec((1, tm, tk), lambda b, rows, cols: (b, 0, 0)),
        pl.BlockSpec((1, tk), lambda b, rows, cols: (cols[b], 0)),
    ]
    out_spec = pl.BlockSpec((1, tm), lambda b, rows, cols: (rows[b], 0))
    out_shape = jax.ShapeDtypeStruct((m_pad // tm, tm), x.dtype)

    if pltpu is not None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nb,),
            in_specs=in_specs,
            out_specs=out_spec,
        )
        # jax renamed TPUCompilerParams -> CompilerParams across releases
        _CompilerParams = getattr(
            pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
        )
        compiler_params = _CompilerParams(
            dimension_semantics=("arbitrary",),
        )
        y2 = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            compiler_params=compiler_params,
            interpret=interpret,
        )(row_ids, col_ids, tiles, x2)
        return y2.reshape(m_pad)

    raise RuntimeError("pallas TPU backend unavailable")  # pragma: no cover
