"""Jitted public wrappers for the Pallas kernels.

``interpret=None`` auto-selects: compiled Mosaic on TPU, interpret mode
(Python-evaluated kernel body) elsewhere, so the same call sites work in
CPU tests and on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .bsr_spmm import bsr_spmm_pallas
from .bsr_spmv import bsr_spmv_pallas

__all__ = ["bsr_spmm", "bsr_spmv"]


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("m_pad", "bn", "interpret"))
def _spmm_jit(tiles, row_ids, col_ids, x, *, m_pad, bn, interpret):
    n = x.shape[1]
    n_pad = -(-n // bn) * bn
    if n_pad != n:
        x = jnp.pad(x, ((0, 0), (0, n_pad - n)))
    y = bsr_spmm_pallas(
        tiles, row_ids, col_ids, x, m_pad=m_pad, bn=bn, interpret=interpret
    )
    return y[:, :n]


def bsr_spmm(tiles, row_ids, col_ids, x, *, m_pad, bn=128, interpret=None):
    """Block-sparse SpMM: y (m_pad, n) from uniform tiles + tables."""
    bn = min(bn, max(int(x.shape[1]), 1))
    return _spmm_jit(
        tiles,
        row_ids,
        col_ids,
        x,
        m_pad=m_pad,
        bn=bn,
        interpret=_auto_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("m_pad", "interpret"))
def _spmv_jit(tiles, row_ids, col_ids, x, *, m_pad, interpret):
    return bsr_spmv_pallas(
        tiles, row_ids, col_ids, x, m_pad=m_pad, interpret=interpret
    )


def bsr_spmv(tiles, row_ids, col_ids, x, *, m_pad, interpret=None):
    """Block-sparse SpMV: y (m_pad,) from uniform tiles + tables."""
    return _spmv_jit(
        tiles, row_ids, col_ids, x, m_pad=m_pad, interpret=_auto_interpret(interpret)
    )
