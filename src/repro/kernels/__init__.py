"""Pallas TPU kernels for the SABLE compute hot-spots."""
from . import ops, ref
from .ops import bsr_spmm, bsr_spmv
