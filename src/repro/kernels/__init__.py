"""Pallas TPU kernels for the SABLE compute hot-spots."""
from . import bsr_ops, dia_hybrid, ops, ref
from .bsr_ops import dds, dsd, sdd
from .dia_hybrid import stage_dia_hybrid
from .ops import bsr_spmm, bsr_spmv
