"""Pallas TPU kernels for the SABLE compute hot-spots."""
from . import bsr_ops, ops, ref
from .bsr_ops import dds, dsd, sdd
from .ops import bsr_spmm, bsr_spmv
