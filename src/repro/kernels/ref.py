"""Pure-jnp oracles for the Pallas block-sparse kernels."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def bsr_spmm_ref(tiles, row_ids, col_ids, x, m_pad):
    """y[row*tm:(row+1)*tm, :] += tile @ x[col*tk:(col+1)*tk, :]."""
    tiles = np.asarray(tiles)
    nb, tm, tk = tiles.shape
    n = x.shape[1]
    y = np.zeros((m_pad, n), dtype=np.result_type(tiles.dtype, np.asarray(x).dtype))
    x = np.asarray(x)
    for b in range(nb):
        r, c = int(row_ids[b]), int(col_ids[b])
        y[r * tm : (r + 1) * tm, :] += tiles[b] @ x[c * tk : (c + 1) * tk, :]
    return jnp.asarray(y)


def bsr_spmv_ref(tiles, row_ids, col_ids, x, m_pad):
    """y[row*tm:(row+1)*tm] += tile @ x[col*tk:(col+1)*tk]."""
    tiles = np.asarray(tiles)
    nb, tm, tk = tiles.shape
    y = np.zeros((m_pad,), dtype=np.result_type(tiles.dtype, np.asarray(x).dtype))
    x = np.asarray(x)
    for b in range(nb):
        r, c = int(row_ids[b]), int(col_ids[b])
        y[r * tm : (r + 1) * tm] += tiles[b] @ x[c * tk : (c + 1) * tk]
    return jnp.asarray(y)


def vbr_spmv_ref(vbr, x):
    """Densify-and-multiply oracle for end-to-end staged SpMV."""
    return jnp.asarray(vbr.to_dense()) @ jnp.asarray(x)


def vbr_spmm_ref(vbr, x):
    return jnp.asarray(vbr.to_dense()) @ jnp.asarray(x)
