"""Pallas TPU kernel: block-sparse SpMM over uniformized VBR tiles.

The staged structure (tile -> (row, col) tables from ``core.uniformize``)
is passed as *scalar-prefetch* operands: Mosaic reads them from SMEM to
compute the DMA schedule, which is exactly the paper's Stage-1 "constant
bounds baked into the code", in TPU form — the HLO/kernel is O(1) in the
number of blocks, the tables are data.

Grid layout: ``(n_j, nb)`` with the dense-column tile ``j`` OUTER and the
block index ``b`` INNER.  Tiles are sorted by output row tile, so all
blocks contributing to one output tile are consecutive grid steps: the
output block stays resident in VMEM and is accumulated, initialized on
first visit (``row changes => new accumulation``).  This is the standard
TPU block-sparse matmul schedule; the MXU sees only dense (tm, tk) x
(tk, bn) products — "compute over some zeros" in its purest form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific pieces degrade gracefully on CPU (interpret mode)
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _kernel(row_ids, col_ids, tiles_ref, x_ref, y_ref, *, acc_dtype):
    b = pl.program_id(1)
    row = row_ids[b]
    prev_row = row_ids[jnp.maximum(b - 1, 0)]
    is_first = jnp.logical_or(b == 0, prev_row != row)

    @pl.when(is_first)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    acc = jnp.dot(
        tiles_ref[0].astype(acc_dtype),
        x_ref[...].astype(acc_dtype),
        preferred_element_type=acc_dtype,
    )
    y_ref[...] += acc.astype(y_ref.dtype)


def bsr_spmm_pallas(
    tiles: jax.Array,  # (nb, tm, tk)
    row_ids: jax.Array,  # (nb,) int32, sorted
    col_ids: jax.Array,  # (nb,) int32
    x: jax.Array,  # (k_pad, n) with n % bn == 0
    *,
    m_pad: int,
    bn: int,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jax.Array:
    nb, tm, tk = tiles.shape
    k_pad, n = x.shape
    assert n % bn == 0, f"n={n} must be a multiple of bn={bn}"
    n_j = n // bn

    grid = (n_j, nb)
    kernel = functools.partial(_kernel, acc_dtype=acc_dtype)

    in_specs = [
        pl.BlockSpec((1, tm, tk), lambda j, b, rows, cols: (b, 0, 0)),
        pl.BlockSpec((tk, bn), lambda j, b, rows, cols: (cols[b], j)),
    ]
    out_spec = pl.BlockSpec((tm, bn), lambda j, b, rows, cols: (rows[b], j))
    out_shape = jax.ShapeDtypeStruct((m_pad, n), x.dtype)

    if pltpu is not None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_spec,
        )
        # jax renamed TPUCompilerParams -> CompilerParams across releases
        _CompilerParams = getattr(
            pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
        )
        compiler_params = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        )
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            compiler_params=compiler_params,
            interpret=interpret,
        )(row_ids, col_ids, tiles, x)

    # pragma: no cover - non-TPU builds without pltpu
    raise RuntimeError("pallas TPU backend unavailable")
