"""The fixed-block sparse product family over ``BlockMatrix``: dsd/dds/sdd.

STK's op family (SNIPPETS.md §1) in Pallas form, for structures that
change every call (per-batch MoE topologies) — no inspection, no staging,
no plan cache.  All three ops take the blocked-CSR-COO arrays as *runtime
data* (scalar-prefetch operands on TPU), so one compiled program serves
every topology of the same ``nnz_max`` bound:

  ``dsd(S, x)``      dense (M,N)  = sparse (M,K) @ dense (K,N)
  ``dds(x, S)``      dense (M,N)  = dense (M,K)  @ sparse (K,N)
  ``sdd(a, b, T)``   sparse       = dense (M,K)  @ dense (K,N), computed
                     only at ``T``'s block topology (sparse *output*)

Each op has a grouped-einsum reference implementation (gather + batched
block matmul + scatter-add, ``backend='grouped'``, the portable/CPU path)
and a Pallas kernel (``backend='pallas'``) reusing the scalar-prefetch
grid schedule of ``bsr_spmm``.  ``backend='auto'`` picks pallas on TPU.

Every op carries a ``custom_vjp`` whose backward passes are themselves
members of the family — the closure property that makes dropless-MoE
training run entirely on these kernels::

  d dsd(S, x) / dx    = dsd(S^T, g)         d/dS    = sdd(g, x^T, S)
  d dds(x, S) / dx    = dds(g, S^T)         d/dS    = sdd(x^T, g, S)
  d sdd(a, b, T) / da = dsd(g_T, b^T)       d/db    = dds(a^T, g_T)

Padding slots (``row == n_block_rows``) ride along as zero blocks:
scatters drop them, gathers read clamped coordinates against zero data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU-specific pieces degrade gracefully on CPU (interpret mode)
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

from ..sparse.block_csr import BlockMatrix
from .ops import bsr_spmm

__all__ = ["dsd", "dds", "sdd"]


def _resolve(backend: str, interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "grouped"
    if backend not in ("grouped", "pallas"):
        raise ValueError(f"unknown bsr_ops backend {backend!r}")
    return backend, bool(interpret)


def _f0(a):
    """float0 cotangent for an integer-valued primal input."""
    return np.zeros(a.shape, jax.dtypes.float0)


# ---------------------------------------------------------------------- #
# sdd pallas kernel: one output block per grid row, K tiled inner
# ---------------------------------------------------------------------- #
def _sdd_kernel(row_ids, col_ids, a_ref, b_ref, o_ref, *, acc_dtype):
    del row_ids, col_ids  # consumed by the index maps
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.dot(
        a_ref[...].astype(acc_dtype),
        b_ref[...].astype(acc_dtype),
        preferred_element_type=acc_dtype,
    )
    o_ref[...] += acc[None].astype(o_ref.dtype)


def _pick_bk(K: int) -> int:
    if K <= 512:
        return K
    for t in (512, 256, 128):
        if K % t == 0:
            return t
    return K


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def _sdd_pallas(a, b, rows, cols, *, bm, bn, interpret):
    """(nnz, bm, bn) blocks of a @ b at (rows, cols); coordinates must be
    pre-clamped in-bounds (invalid slots are zeroed by the caller)."""
    if pltpu is None:  # pragma: no cover - non-TPU builds without pltpu
        raise RuntimeError("pallas TPU backend unavailable")
    nb = rows.shape[0]
    K = a.shape[1]
    bk = _pick_bk(K)
    grid = (nb, K // bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k, rows, cols: (rows[i], k)),
            pl.BlockSpec((bk, bn), lambda i, k, rows, cols: (k, cols[i])),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda i, k, rows, cols: (i, 0, 0)),
    )
    _CompilerParams = getattr(
        pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
    )
    return pl.pallas_call(
        functools.partial(_sdd_kernel, acc_dtype=jnp.float32),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, bm, bn), a.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(rows, cols, a, b)


# ---------------------------------------------------------------------- #
# dsd: dense = sparse @ dense
# ---------------------------------------------------------------------- #
def _dsd_impl(spec, data, rows, cols, x):
    (M, K), (bm, bk), backend, interpret = spec
    Rb, Kb = M // bm, K // bk
    N = x.shape[1]
    if backend == "pallas":
        # padded slots target the extra (Rb+1)-th block row, sliced off;
        # their zero data makes the clamped column reads harmless
        y = bsr_spmm(
            data, rows, jnp.minimum(cols, Kb - 1), x,
            m_pad=(Rb + 1) * bm, interpret=interpret,
        )[:M]
        # block rows with no blocks are never visited by the accumulation
        # schedule — zero them explicitly
        covered = jnp.zeros((Rb,), bool).at[rows].set(True, mode="drop")
        return jnp.where(jnp.repeat(covered, bm)[:, None], y, 0)
    xg = x.reshape(Kb, bk, N)[jnp.minimum(cols, Kb - 1)]  # (nnz, bk, N)
    part = jnp.einsum("bmk,bkn->bmn", data, xg)
    y = jnp.zeros((Rb, bm, N), part.dtype).at[rows].add(part, mode="drop")
    return y.reshape(M, N)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dsd_core(spec, data, rows, cols, x):
    return _dsd_impl(spec, data, rows, cols, x)


def _dsd_fwd(spec, data, rows, cols, x):
    return _dsd_impl(spec, data, rows, cols, x), (data, rows, cols, x)


def _dsd_bwd(spec, res, g):
    data, rows, cols, x = res
    (M, K), (bm, bk), backend, interpret = spec
    sp = BlockMatrix.from_coo((M, K), (bm, bk), data, rows, cols)
    dx = dsd(sp.transpose(), g, backend=backend, interpret=interpret)
    dsp = sdd(g, x.T, sp, backend=backend, interpret=interpret)
    return (
        dsp.data.astype(data.dtype),
        _f0(rows),
        _f0(cols),
        dx.astype(x.dtype),
    )


_dsd_core.defvjp(_dsd_fwd, _dsd_bwd)


def dsd(sp: BlockMatrix, x: jnp.ndarray, *, backend: str = "auto",
        interpret=None) -> jnp.ndarray:
    """dense (M, N) = sparse (M, K) @ dense (K, N)."""
    assert x.ndim == 2 and x.shape[0] == sp.shape[1], (
        f"dsd: x {x.shape} does not match sparse {sp.shape}"
    )
    backend, interpret = _resolve(backend, interpret)
    spec = (tuple(sp.shape), tuple(sp.block), backend, interpret)
    return _dsd_core(spec, sp.data, sp.row_indices, sp.column_indices, x)


# ---------------------------------------------------------------------- #
# dds: dense = dense @ sparse
# ---------------------------------------------------------------------- #
def _dds_impl(spec, x, data, rows, cols):
    (K, N), (bm, bn), backend, interpret = spec
    Kb, Nb = K // bm, N // bn
    M = x.shape[0]
    if backend == "pallas":
        # x @ S == (S^T @ x^T)^T — reuse the dsd schedule on the transpose
        spT = BlockMatrix.from_coo((K, N), (bm, bn), data, rows, cols
                                   ).transpose()
        return dsd(spT, x.T, backend=backend, interpret=interpret).T
    xg = x.reshape(M, Kb, bm)[:, jnp.minimum(rows, Kb - 1)]  # (M, nnz, bm)
    part = jnp.einsum("mbt,btk->mbk", xg, data)
    # invalid slots scatter zeros into block-col 0 — harmless
    y = jnp.zeros((M, Nb, bn), part.dtype).at[:, cols].add(part, mode="drop")
    return y.reshape(M, N)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dds_core(spec, x, data, rows, cols):
    return _dds_impl(spec, x, data, rows, cols)


def _dds_fwd(spec, x, data, rows, cols):
    return _dds_impl(spec, x, data, rows, cols), (x, data, rows, cols)


def _dds_bwd(spec, res, g):
    x, data, rows, cols = res
    (K, N), (bm, bn), backend, interpret = spec
    sp = BlockMatrix.from_coo((K, N), (bm, bn), data, rows, cols)
    dx = dds(g, sp.transpose(), backend=backend, interpret=interpret)
    dsp = sdd(x.T, g, sp, backend=backend, interpret=interpret)
    return (
        dx.astype(x.dtype),
        dsp.data.astype(data.dtype),
        _f0(rows),
        _f0(cols),
    )


_dds_core.defvjp(_dds_fwd, _dds_bwd)


def dds(x: jnp.ndarray, sp: BlockMatrix, *, backend: str = "auto",
        interpret=None) -> jnp.ndarray:
    """dense (M, N) = dense (M, K) @ sparse (K, N)."""
    assert x.ndim == 2 and x.shape[1] == sp.shape[0], (
        f"dds: x {x.shape} does not match sparse {sp.shape}"
    )
    backend, interpret = _resolve(backend, interpret)
    spec = (tuple(sp.shape), tuple(sp.block), backend, interpret)
    return _dds_core(spec, x, sp.data, sp.row_indices, sp.column_indices)


# ---------------------------------------------------------------------- #
# sdd: sparse output = dense @ dense under a topology mask
# ---------------------------------------------------------------------- #
def _sdd_impl(spec, a, b, rows, cols):
    (M, N), (bm, bn), backend, interpret = spec
    Rb, Cb = M // bm, N // bn
    valid = rows < Rb
    rc = jnp.minimum(rows, Rb - 1)
    cc = jnp.minimum(cols, Cb - 1)
    if backend == "pallas":
        data = _sdd_pallas(a, b, rc, cc, bm=bm, bn=bn, interpret=interpret)
    else:
        ag = a.reshape(Rb, bm, a.shape[1])[rc]  # (nnz, bm, K)
        bg = b.reshape(b.shape[0], Cb, bn).transpose(1, 0, 2)[cc]
        data = jnp.einsum("bmk,bkn->bmn", ag, bg)
    return jnp.where(valid[:, None, None], data, 0.0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sdd_core(spec, a, b, rows, cols):
    return _sdd_impl(spec, a, b, rows, cols)


def _sdd_fwd(spec, a, b, rows, cols):
    return _sdd_impl(spec, a, b, rows, cols), (a, b, rows, cols)


def _sdd_bwd(spec, res, g):
    a, b, rows, cols = res
    (M, N), (bm, bn), backend, interpret = spec
    g_sp = BlockMatrix.from_coo((M, N), (bm, bn), g, rows, cols)
    da = dsd(g_sp, b.T, backend=backend, interpret=interpret)
    db = dds(a.T, g_sp, backend=backend, interpret=interpret)
    return da.astype(a.dtype), db.astype(b.dtype), _f0(rows), _f0(cols)


_sdd_core.defvjp(_sdd_fwd, _sdd_bwd)


def sdd(a: jnp.ndarray, b: jnp.ndarray, topo: BlockMatrix, *,
        backend: str = "auto", interpret=None) -> BlockMatrix:
    """sparse (M, N) = dense (M, K) @ dense (K, N), computed only at
    ``topo``'s blocks.  Returns a BlockMatrix sharing ``topo``'s
    structure arrays (same slot order — elementwise ops on ``.data``
    stay aligned across same-topology products)."""
    (M, N) = topo.shape
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0], (
        f"sdd: inner dims {a.shape} @ {b.shape}"
    )
    assert a.shape[0] == M and b.shape[1] == N, (
        f"sdd: output {a.shape[0]}x{b.shape[1]} vs topology {topo.shape}"
    )
    backend, interpret = _resolve(backend, interpret)
    spec = (tuple(topo.shape), tuple(topo.block), backend, interpret)
    data = _sdd_core(spec, a, b, topo.row_indices, topo.column_indices)
    return BlockMatrix(
        tuple(topo.shape), tuple(topo.block), data,
        topo.row_indices, topo.column_indices, topo.offsets,
    )
