"""Continuous-batching request scheduler over the paged KV cache.

The paper's amortization thesis at request granularity: a server admits and
retires sequences *mid-decode* (continuous batching) instead of running
fixed generate() batches, and every admission consults the persistent plan
cache (core/cache.py) so a structure whose plan is already warm fast-paths
straight to decode while cold structures are staged off the decode path
(at most ``cold_stage_budget`` patterns per scheduler iteration).

Scheduler states::

    WAITING ──admit (pages + lane free)──▶ RUNNING ──len(tokens)==max──▶ FINISHED
       ▲                                     │
       └──────── resume (lossless) ◀── PREEMPTED (pages parked on host)

One ``step()`` is one deterministic scheduling iteration: (0) stage cold
plans, (1) admit/resume from the queue, (1b) advance every mid-prefill
lane by one chunk (``chunked_prefill``), (2) grow page tables for this
step's write position — evicting the youngest-arrival lane under page
pressure — (3) one batched decode step over all running lanes, (4) retire
finished sequences.  Determinism is total given a fixed submission order
and clock: tests drive it with a fake clock and golden transcripts freeze
the admit/evict/page-table sequence.

Two opt-in features reuse the prompt across requests / unblock decode
under long prompts (both default off — the golden transcript pins the
plain schedule):

- ``prefix_sharing``: admission maps the page-aligned prompt prefix onto
  already-resident pages via the cache's prefix index (refcount + COW, see
  paged_cache.py) and prefills only the unshared tail — N requests with a
  common system prompt pay its pages and FLOPs once.
- ``chunked_prefill``: prompts prefill ``prefill_chunk`` tokens per
  scheduler step, interleaved with decode, instead of monopolizing a step;
  a mid-prefill lane holds pages but neither decodes nor blocks others,
  and eviction mid-prefill is lossless (resume continues at the next
  chunk).  Both features require a fully-paged cache (attention-only
  decoder): SSM/conv state summarizes the whole prefix and can be neither
  inherited from shared pages nor rebuilt chunk-by-chunk.

Decode is a single jitted ``vmap`` over lanes — each lane carries its own
cache view, position, RNG key, and temperature, so a lane's computation is
exactly the single-sequence ``decode_step`` and output tokens match N
independent ``ServeEngine.generate`` runs token-for-token.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import decode_step, init_cache, prefill
from ..models.transformer import prefill_chunk as _prefill_chunk_fn
from .paged_cache import PagedKVCache, PagesExhausted

__all__ = ["Request", "ContinuousBatchingScheduler"]

WAITING, RUNNING, PREEMPTED, FINISHED = (
    "WAITING",
    "RUNNING",
    "PREEMPTED",
    "FINISHED",
)

_RID = itertools.count()


@dataclasses.dataclass(eq=False)  # identity semantics (numpy fields)
class Request:
    """One generation request.  ``patterns`` (optional BlockPatterns) are
    the request's sparse structures for plan-warm admission; empty means
    dense / always warm."""

    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int
    temperature: float = 0.0
    rng: Optional[jnp.ndarray] = None  # per-request PRNG key (sampling)
    patterns: tuple = ()
    rid: str = ""
    arrival: float = 0.0
    state: str = WAITING
    tokens: List[int] = dataclasses.field(default_factory=list)
    logits: list = dataclasses.field(default_factory=list)
    skips: int = 0  # times passed over by warm-first admission (aging)
    prefilled: int = 0  # prompt positions whose KV is resident (shared or computed)
    metrics: dict = dataclasses.field(default_factory=dict)

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    def output(self) -> np.ndarray:
        return np.concatenate(
            [np.asarray(self.prompt, np.int32), np.asarray(self.tokens, np.int32)]
        )


def _make_lane_step(cfg: ModelConfig, paged_mask):
    """Jitted per-step decoder: vmap of the single-sequence decode over
    lanes with per-lane (cache view, position, key, temperature).  Returns
    (next_token (B,), logits (B, V) f32, written-slice pytree)."""

    def one(params, tok, cache_b, pos, rng, temp):
        cache1 = jax.tree.map(lambda a: a[:, None], cache_b)  # re-add B=1
        logits, nc = decode_step(params, cfg, tok[None], cache1, pos)
        row = logits[:, 0].astype(jnp.float32)  # (1, V) — engine layout
        greedy = jnp.argmax(row, axis=-1)
        sampled = jax.random.categorical(
            rng, row / jnp.maximum(temp, 1e-6)
        )
        nxt = jnp.where(temp > 0, sampled, greedy).astype(jnp.int32)
        sl = jax.tree.map(
            lambda a, m: (
                jax.lax.dynamic_slice_in_dim(a, pos, 1, axis=2)[:, 0, 0]
                if m
                else a
            ),
            nc,
            paged_mask,
        )
        return nxt[0], row[0], sl

    return jax.jit(jax.vmap(one, in_axes=(None, 0, 1, 0, 0, 0)))


class ContinuousBatchingScheduler:
    """See module docstring.  ``policy``: "fcfs" (strict arrival order) or
    "warm_first" (plan-warm requests admit ahead of cold ones, with aging:
    a request skipped ``max_skips`` times regains head-of-line priority, so
    cold requests cannot starve).

    ``cold_cost_scoring=True`` refines warm_first with the learned cost
    model (``core/cost_model.py``): when no warm request exists, cold
    requests are admitted cheapest-predicted-staging-cost first (and
    ``_stage_cold`` stages cheapest first) instead of treating all cold as
    equal — many cheap structures warm per unit of staging time before one
    expensive one.  Off by default: scoring changes admission order, and
    golden transcripts pin the unscored schedule."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_len: int,
        page_size: int = 16,
        num_pages: Optional[int] = None,
        max_batch: int = 4,
        policy: str = "fcfs",
        cold_stage_budget: int = 1,
        max_skips: int = 4,
        cold_cost_scoring: bool = False,
        clock=None,
        mesh=None,
        plan_cache=None,
        record_logits: bool = False,
        prefix_sharing: bool = False,
        chunked_prefill: bool = False,
        prefill_chunk: Optional[int] = None,
    ):
        if policy not in ("fcfs", "warm_first"):
            raise ValueError(f"unknown admission policy {policy!r}")
        self.cfg = cfg
        self.params = params
        self.max_len = int(max_len)
        self.max_batch = int(max_batch)
        self.policy = policy
        self.cold_stage_budget = int(cold_stage_budget)
        self.max_skips = int(max_skips)
        self.cold_cost_scoring = bool(cold_cost_scoring)
        self._stage_cost_model = False  # False = not resolved yet
        self.clock = clock if clock is not None else time.perf_counter
        self.mesh = mesh
        self.plan_cache = plan_cache
        self.record_logits = record_logits
        self.prefix_sharing = bool(prefix_sharing)
        self.chunked_prefill = bool(chunked_prefill)
        self.prefill_chunk = (
            2 * int(page_size) if prefill_chunk is None else int(prefill_chunk)
        )
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")

        import math

        view_pages = math.ceil(self.max_len / page_size)
        if num_pages is None:
            num_pages = self.max_batch * view_pages
        self.kv = PagedKVCache(
            cfg, num_pages, page_size, self.max_len,
            prefix_sharing=self.prefix_sharing,
        )
        if (self.prefix_sharing or self.chunked_prefill) and not all(
            self.kv.paged
        ):
            raise ValueError(
                "prefix_sharing/chunked_prefill need a fully-paged cache "
                "(attention-only decoder): SSM/conv state summarizes the "
                "whole prefix and cannot be shared or rebuilt per chunk"
            )

        self._prefill = jax.jit(
            lambda params, toks, cache: prefill(params, cfg, toks, cache)
        )
        self._prefill_chunk = jax.jit(
            lambda params, toks, cache, start: _prefill_chunk_fn(
                params, cfg, toks, cache, start
            )
        )
        # fixed dense width for chunk compute: one retrace per chunk length
        self._prefill_width = self.kv.view_pages * int(page_size)
        self._lane_step = _make_lane_step(cfg, self.kv.paged_mask)

        self.queue: List[Request] = []  # kept in arrival order
        self.lanes: List[Optional[Request]] = [None] * self.max_batch
        self.requests: dict = {}
        self.transcript: list = []
        self.stats = {
            "steps": 0,
            "admissions": 0,
            "evictions": 0,
            "resumes": 0,
            "finished": 0,
            "plans_staged": 0,
            "decode_tokens": 0,
            "prefill_tokens": 0,
            "prefill_chunks": 0,
            "prefix_hits": 0,
            "pages_shared": 0,
            "cow_copies": 0,
            "shared_releases": 0,
        }

    # ------------------------------------------------------------------ #
    # submission
    # ------------------------------------------------------------------ #
    def submit(
        self,
        prompt,
        max_new_tokens: int,
        temperature: float = 0.0,
        rng=None,
        patterns=(),
        rid: Optional[str] = None,
        arrival: Optional[float] = None,
    ) -> str:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) + max_new_tokens - 1 > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + gen ({max_new_tokens}) exceeds "
                f"max_len={self.max_len}"
            )
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        req = Request(
            prompt=prompt,
            max_new_tokens=int(max_new_tokens),
            temperature=float(temperature),
            rng=rng if rng is not None else jax.random.PRNGKey(0),
            patterns=tuple(patterns),
            rid=rid if rid is not None else f"req{next(_RID)}",
            arrival=self.clock() if arrival is None else float(arrival),
        )
        if req.rid in self.requests:
            raise ValueError(f"duplicate rid {req.rid!r}")
        self.requests[req.rid] = req
        # arrival-ordered insert (preempted re-entries use the same path)
        self._enqueue(req)
        return req.rid

    def _enqueue(self, req: Request) -> None:
        i = len(self.queue)
        while i > 0 and self.queue[i - 1].arrival > req.arrival:
            i -= 1
        self.queue.insert(i, req)

    # ------------------------------------------------------------------ #
    # plan-warm admission
    # ------------------------------------------------------------------ #
    def _plan_keys(self, pattern) -> List[str]:
        from ..core import cache as cachelib
        from ..sparse.linear import pattern_hash

        device = jax.default_backend()
        h = pattern_hash(pattern)
        keys = [cachelib.plan_key("linear", h, device)]
        if self.mesh is not None:
            from ..core.sharded import resolve_shard_axis

            try:
                axis = resolve_shard_axis(self.mesh, "shards")
            except ValueError:
                axis = None
            if axis is not None:
                n = int(self.mesh.shape[axis])
                keys += [
                    cachelib.plan_key(
                        "linear", h, device, shard_id=i, num_shards=n
                    )
                    for i in range(n)
                ]
        return keys

    def _store(self):
        from ..core import cache as cachelib

        return (
            self.plan_cache
            if self.plan_cache is not None
            else cachelib.default_cache()
        )

    def _is_warm(self, req: Request) -> bool:
        store = self._store()
        return all(
            store.has_plan(k)
            for p in req.patterns
            for k in self._plan_keys(p)
        )

    # ------------------------------------------------------------------ #
    # predicted staging cost (cold_cost_scoring)
    # ------------------------------------------------------------------ #
    def _cost_model(self):
        """Lazily resolve the ``linear`` cost model over this scheduler's
        plan cache; None (no/too-small corpus) degrades scoring to the
        unscored behavior."""
        if self._stage_cost_model is False:
            from ..core import cost_model as cmlib

            self._stage_cost_model = cmlib.load_or_fit(
                self._store(), jax.default_backend(), "linear"
            )
        return self._stage_cost_model

    def _predicted_stage_cost(self, req: Request) -> float:
        """Predicted seconds to stage this request's still-cold patterns
        (sum over candidates — measuring times them all).  0.0 when warm;
        inf for a pattern the model cannot score (most expensive
        assumption, so scoreable work goes first)."""
        model = self._cost_model()
        if model is None:
            return 0.0
        from ..core import cost_model as cmlib

        store = self._store()
        total = 0.0
        for p in req.patterns:
            if all(store.has_plan(k) for k in self._plan_keys(p)):
                continue
            feats = cmlib.pattern_features(p)
            if model.nn_distance(feats) > cmlib.DEFAULT_MAX_DISTANCE:
                return float("inf")
            total += model.staging_cost(feats)
        return total

    def _stage_cold(self, ev: dict) -> None:
        """Stage up to ``cold_stage_budget`` cold patterns from the queue —
        off the decode path (decode proceeds this same iteration)."""
        if self.cold_stage_budget <= 0:
            return
        from ..sparse.linear import pattern_hash, warm_matmul_plans

        store = self._store()
        budget = self.cold_stage_budget
        seen = set()
        # waiting requests first, then running lanes: admission may outrun
        # staging (fcfs admits cold requests too), but every submitted
        # pattern must end up staged so the next process restarts warm
        pool = list(self.queue) + [r for r in self.lanes if r is not None]
        if self.cold_cost_scoring:
            # cheapest predicted staging first: the bounded budget warms
            # the most structures per scheduler iteration
            pool = sorted(
                enumerate(pool),
                key=lambda ir: (self._predicted_stage_cost(ir[1]), ir[0]),
            )
            pool = [r for _, r in pool]
        for req in pool:
            for p in req.patterns:
                h = pattern_hash(p)
                if h in seen:
                    continue
                seen.add(h)
                keys = self._plan_keys(p)
                cold = [k for k in keys if not store.has_plan(k)]
                if not cold:
                    continue
                warm_matmul_plans([p], cache=self.plan_cache, mesh=self.mesh)
                staged = sum(1 for k in cold if store.has_plan(k))
                self.stats["plans_staged"] += staged
                ev["staged"].append(h)
                budget -= 1
                if budget <= 0:
                    return

    # ------------------------------------------------------------------ #
    # admission / eviction
    # ------------------------------------------------------------------ #
    def _pick_next(self) -> Optional[int]:
        if not self.queue:
            return None
        if self.policy == "fcfs":
            return 0
        # warm_first with aging: an over-skipped head wins unconditionally
        if self.queue[0].skips >= self.max_skips:
            return 0
        for i, r in enumerate(self.queue):
            if self._is_warm(r):
                for o in self.queue[:i]:
                    o.skips += 1
                return i
        # every queued request is cold: score by predicted staging cost
        # (cheapest first) when enabled, else strict arrival order
        if self.cold_cost_scoring and len(self.queue) > 1:
            costs = [self._predicted_stage_cost(r) for r in self.queue]
            i = min(range(len(costs)), key=lambda j: (costs[j], j))
            if i > 0:
                for o in self.queue[:i]:
                    o.skips += 1
            return i
        return 0

    def _admit(self, now: float, ev: dict) -> None:
        while True:
            free = [i for i, r in enumerate(self.lanes) if r is None]
            if not free or not self.queue:
                return
            qi = self._pick_next()
            if qi is None:
                return
            req = self.queue[qi]
            if req.state == PREEMPTED:
                if not self.kv.resume(req.rid):
                    return  # head-of-line blocking on pages: deterministic
                self.stats["resumes"] += 1
                ev["resumed"].append(req.rid)
            elif self.prefix_sharing or self.chunked_prefill:
                if not self._begin_prefill(req, now, ev):
                    return
                ev["admitted"].append(req.rid)
            else:
                if not self.kv.alloc_seq(req.rid, req.prompt_len, zero=False):
                    return
                self._prefill_request(req, now)
                ev["admitted"].append(req.rid)
            self.queue.pop(qi)
            req.state = RUNNING
            self.stats["admissions"] += 1
            req.metrics.setdefault("admitted_at", now)
            if len(req.tokens) >= req.max_new_tokens:
                self._finish(req, free[0], now, ev, lane_assigned=False)
            else:
                self.lanes[free[0]] = req

    def _prefill_request(self, req: Request, now: float) -> None:
        """Whole-prompt prefill at admission — the plain path (both features
        off); frozen by the golden transcript, so it stays byte-stable."""
        P = req.prompt_len
        cache = init_cache(self.cfg, 1, P)
        logits, cache = self._prefill(
            self.params, jnp.asarray(req.prompt[None]), cache
        )
        row = logits[:, -1].astype(jnp.float32)  # (1, V)
        first = int(jnp.argmax(row, axis=-1)[0])
        self.kv.write_prefill(req.rid, cache, P)
        self.stats["prefill_tokens"] += P
        req.prefilled = P
        req.tokens.append(first)
        if self.record_logits:
            req.logits.append(np.asarray(row[0]))
        req.metrics.setdefault("first_token_at", now)

    # ------------------------------------------------------------------ #
    # prefix-shared / chunked prefill
    # ------------------------------------------------------------------ #
    def _begin_prefill(self, req: Request, now: float, ev: dict) -> bool:
        """Admission for the sharing/chunked path: attach the page-aligned
        shared prompt prefix by reference (pages + FLOPs skipped), reserve
        pages for the whole tail — or only the first chunk when chunking —
        and prefill the tail in one shot unless ``chunked_prefill`` defers
        it to ``_advance_prefills``.  False = not enough pages, admission
        blocks head-of-line (deterministic, like the plain path)."""
        P = req.prompt_len
        ok = self.kv.alloc_seq(
            req.rid,
            P,
            tokens=req.prompt if self.prefix_sharing else None,
            reserve=self.prefill_chunk if self.chunked_prefill else None,
            zero=False,
        )
        if not ok:
            return False
        req.prefilled = self.kv.seq_len[req.rid]  # == shared span
        if req.prefilled:
            ev["shared"][req.rid] = req.prefilled
        if not self.chunked_prefill:
            self._prefill_one_chunk(req, now, ev, in_admit=True)
        return True

    def _prefill_one_chunk(
        self, req: Request, now: float, ev: dict, in_admit: bool = False
    ) -> None:
        """Advance one mid-prefill sequence by one chunk (or the whole
        remaining tail when chunking is off).  The final chunk emits the
        first generated token from its last-position logits, exactly like
        whole-prompt prefill.  Page pressure parks other lanes per policy;
        if nothing is left to evict, this sequence parks itself losslessly
        (the computed chunk is dropped, ``prefilled`` does not advance)."""
        P = req.prompt_len
        if self.prefix_sharing and self.chunked_prefill:
            # the prefix writer may have registered pages since our last
            # chunk (or since admission): attach instead of recomputing
            if self.kv.attach_shared(req.rid):
                req.prefilled = self.kv.seq_len[req.rid]
                ev["shared"][req.rid] = req.prefilled
        start = req.prefilled
        end = P if not self.chunked_prefill else min(P, start + self.prefill_chunk)
        while not self.kv.ensure_capacity(req.rid, end, zero=False):
            others = [
                r for r in self.lanes if r is not None and r is not req
            ]
            if others:
                victim = max(others, key=lambda r: (r.arrival, r.rid))
                self._evict(victim, ev)
                continue
            if self._release_parked_shared_one():
                continue
            if in_admit:  # capacity was reserved at alloc; unreachable
                raise PagesExhausted(f"admission reserve lost for {req.rid!r}")
            self._evict(req, ev)
            return
        dense = self.kv.read_dense(req.rid, s_max=self._prefill_width)
        logits, dense = self._prefill_chunk(
            self.params,
            jnp.asarray(req.prompt[None, start:end]),
            dense,
            jnp.int32(start),
        )
        self.kv.write_span(req.rid, dense, start, end)
        req.prefilled = end
        self.stats["prefill_tokens"] += end - start
        self.stats["prefill_chunks"] += 1
        ev["prefill"][req.rid] = [start, end]
        if end == P:
            row = logits[:, -1].astype(jnp.float32)  # (1, V)
            req.tokens.append(int(jnp.argmax(row, axis=-1)[0]))
            if self.record_logits:
                req.logits.append(np.asarray(row[0]))
            req.metrics.setdefault("first_token_at", now)

    def _advance_prefills(self, now: float, ev: dict) -> None:
        """One chunk per mid-prefill lane per step, oldest arrival first —
        interleaved with decode so long prompts never stall running lanes."""
        if not self.chunked_prefill:
            return
        order = sorted(
            (
                i
                for i, r in enumerate(self.lanes)
                if r is not None and r.prefilled < r.prompt_len
            ),
            key=lambda i: (self.lanes[i].arrival, self.lanes[i].rid),
        )
        for i in order:
            req = self.lanes[i]
            if req is None or req.prefilled >= req.prompt_len:
                continue  # evicted by an earlier lane's page pressure
            self._prefill_one_chunk(req, now, ev)
            # max_new_tokens == 1: the final chunk's token is the output
            if (
                self.lanes[i] is req
                and req.tokens
                and len(req.tokens) >= req.max_new_tokens
            ):
                self._finish(req, i, now, ev)

    def _release_parked_shared_one(self) -> bool:
        """Terminal-pressure escape valve: demote the youngest parked
        sequence's retained shared pages to host copies so the arena can
        actually drain.  False when no parked sequence holds shared pages
        (always, with sharing off — the plain eviction order is untouched)."""
        if not self.prefix_sharing:
            return False
        parked = [
            r
            for r in self.queue
            if r.state == PREEMPTED
            and self.kv.is_parked(r.rid)
            and self.kv.parked_shared_pages(r.rid) > 0
        ]
        if not parked:
            return False
        victim = max(parked, key=lambda r: (r.arrival, r.rid))
        self.kv.release_parked_shared(victim.rid)
        self.stats["shared_releases"] += 1
        return True

    def _evict(self, req: Request, ev: dict) -> None:
        lane = self.lanes.index(req)
        self.kv.evict(req.rid)
        self.lanes[lane] = None
        req.state = PREEMPTED
        self.stats["evictions"] += 1
        ev["evicted"].append(req.rid)
        self._enqueue(req)

    def _ensure_growth(self, ev: dict) -> List[int]:
        """Reserve this step's write position for every decoding lane,
        evicting the youngest-arrival lane under page pressure.  Returns
        the lane indices that will decode this step (mid-prefill lanes hold
        pages but neither grow nor decode here)."""
        order = sorted(
            (i for i, r in enumerate(self.lanes) if r is not None),
            key=lambda i: (self.lanes[i].arrival, self.lanes[i].rid),
        )
        for i in list(order):
            req = self.lanes[i]
            if req is None or not req.tokens:
                continue
            # this step consumes tokens[-1], writing its KV at position
            # prompt_len + len(tokens) - 1 — reserve exactly that
            while not self.kv.ensure_capacity(
                req.rid, req.prompt_len + len(req.tokens)
            ):
                running = [r for r in self.lanes if r is not None]
                victim = max(running, key=lambda r: (r.arrival, r.rid))
                if victim is req and self._release_parked_shared_one():
                    continue
                self._evict(victim, ev)
                if victim is req:
                    break
        return [i for i, r in enumerate(self.lanes) if r is not None and r.tokens]

    def _finish(self, req, lane, now, ev, lane_assigned=True) -> None:
        self.kv.free_seq(req.rid)
        if lane_assigned:
            self.lanes[lane] = None
        req.state = FINISHED
        req.metrics["finished_at"] = now
        self.stats["finished"] += 1
        ev["finished"].append(req.rid)

    # ------------------------------------------------------------------ #
    # the scheduling iteration
    # ------------------------------------------------------------------ #
    def step(self) -> dict:
        now = self.clock()
        ev = {
            "step": self.stats["steps"],
            "admitted": [],
            "resumed": [],
            "evicted": [],
            "finished": [],
            "staged": [],
            "running": [],
            "page_tables": {},
        }
        if self.prefix_sharing or self.chunked_prefill:
            # gated: the frozen transcript compares events by full-dict
            # equality, so the plain schedule must not grow keys
            ev["shared"] = {}
            ev["prefill"] = {}
        self._stage_cold(ev)
        self._admit(now, ev)
        self._advance_prefills(now, ev)
        active = self._ensure_growth(ev)
        ev["running"] = [self.lanes[i].rid for i in active]
        ev["page_tables"] = {
            self.lanes[i].rid: list(self.kv.page_table[self.lanes[i].rid])
            for i in active
        }
        if active:
            self._decode_once(active, ev)
        self.stats["steps"] += 1
        for k, v in self.kv.share_stats.items():
            self.stats[k] = v
        self.transcript.append(ev)
        return ev

    def _decode_once(self, active: List[int], ev: dict) -> None:
        B = self.max_batch
        toks = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        keys = []
        zero_key = np.zeros_like(np.asarray(jax.random.PRNGKey(0)))
        active_set = set(active)
        lane_reqs = {}
        rng_before = {}
        for i in range(B):
            req = self.lanes[i] if i in active_set else None
            if req is None:
                keys.append(zero_key)
                continue
            lane_reqs[i] = req
            toks[i, 0] = req.tokens[-1]
            pos[i] = req.prompt_len + len(req.tokens) - 1
            temps[i] = req.temperature
            # mirror ServeEngine.generate: split every step, sample with sub
            rng_before[i] = req.rng  # rewound if this step's write is lost
            req.rng, sub = jax.random.split(req.rng)
            keys.append(np.asarray(sub))
        view = self.kv.gather(
            [lane_reqs[i].rid if i in lane_reqs else None for i in range(B)]
        )
        nxt, logits, slices = self._lane_step(
            self.params,
            jnp.asarray(toks),
            view,
            jnp.asarray(pos),
            jnp.asarray(np.stack(keys)),
            jnp.asarray(temps),
        )
        nxt = np.asarray(nxt)
        logits = np.asarray(logits)
        flat, _ = jax.tree_util.tree_flatten(slices)
        flat = [np.asarray(leaf) for leaf in flat]
        now = self.clock()
        for i in active:
            req = lane_reqs[i]
            if self.lanes[i] is not req:
                # parked by an earlier lane's page pressure before its own
                # append: this step's write is lost, so rewind the rng split
                # — the redone step after resume samples identically
                req.rng = rng_before[i]
                continue
            slices_i = [leaf[i] for leaf in flat]
            while True:
                try:
                    self.kv.append_token(req.rid, slices_i, int(pos[i]))
                    break
                except PagesExhausted:
                    # COW or growth needed a page mid-append: evict per
                    # policy (youngest other lane first), then the shared-
                    # page escape valve, then park this lane losslessly
                    others = [
                        r
                        for r in self.lanes
                        if r is not None and r is not req
                    ]
                    if others:
                        victim = max(
                            others, key=lambda r: (r.arrival, r.rid)
                        )
                        self._evict(victim, ev)
                        continue
                    if self._release_parked_shared_one():
                        continue
                    self._evict(req, ev)
                    req.rng = rng_before[i]
                    break
            if self.lanes[i] is not req:
                continue  # parked itself above
            req.tokens.append(int(nxt[i]))
            if self.record_logits:
                req.logits.append(logits[i])
            self.stats["decode_tokens"] += 1
            if len(req.tokens) >= req.max_new_tokens:
                self._finish(req, i, now, ev)

    # ------------------------------------------------------------------ #
    def pending(self) -> int:
        return len(self.queue) + sum(r is not None for r in self.lanes)

    def run(self, max_steps: int = 100_000) -> dict:
        """Drive ``step()`` until every submitted request finished."""
        while self.pending() and self.stats["steps"] < max_steps:
            self.step()
        if self.pending():
            raise RuntimeError(
                f"scheduler did not drain in {max_steps} steps "
                f"(queue={len(self.queue)})"
            )
        return {
            rid: {
                "tokens": req.output(),
                "prompt_len": req.prompt_len,
                "metrics": dict(req.metrics),
                "state": req.state,
            }
            for rid, req in self.requests.items()
        }
