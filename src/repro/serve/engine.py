"""Serving engine: a thin facade over the continuous-batching scheduler.

Request-level serving lives in ``serve/scheduler.py`` (continuous
batching over the paged KV cache in ``serve/paged_cache.py``); the engine
owns the model (cfg, params, mesh) and the plan warmup, and hands both to
schedulers it creates:

  engine = ServeEngine(cfg, params, max_len=96)
  results, sched = engine.serve(
      [{"prompt": p1, "max_new_tokens": 16},
       {"prompt": p2, "max_new_tokens": 32, "temperature": 0.8}])

``generate()`` is the original single-batch API, kept as a compatibility
shim (prefill once + lockstep decode on one preallocated dense cache);
its numerics are the reference the scheduler path is regression-pinned
against — N concurrent scheduler requests decode token-identically to N
independent ``generate`` calls.

Startup warmup resolves sparse-matmul plans BEFORE any jit trace and is
restart-aware: when the persistent plan cache (core/cache.py) already
holds every plan for the active device (and per-shard keys for ``mesh=``),
the warmup only loads them — zero re-staging, zero re-benchmarks —
reported in ``warmup_stats``.
"""
from __future__ import annotations

import functools
import itertools
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.transformer import decode_step, encode, init_cache, prefill

# warn-once flag for the enc-dec serve() fallback (tests reset it to
# re-assert the warning fires)
_ENCDEC_FALLBACK_WARNED = False
_FALLBACK_RID = itertools.count()


def _warn_encdec_fallback() -> None:
    global _ENCDEC_FALLBACK_WARNED
    if _ENCDEC_FALLBACK_WARNED:
        return
    _ENCDEC_FALLBACK_WARNED = True
    warnings.warn(
        "enc-dec config: the paged KV cache only pages self-attention "
        "KV (cross-attention KV is per-request static), so serve() is "
        "running the single-batch generate() fallback — no continuous "
        "batching, no paging (warmup_stats['paged'] = False)",
        UserWarning,
        stacklevel=3,
    )


def _has_sparse_ffn(params, patterns) -> bool:
    """True iff the FFN weights are actually tiled for one of the sable
    ``patterns`` — i.e. some w1/w2/w3 leaf ends in (n_tiles, tm, tk).
    Layer stacking may prepend a scan dim, so only trailing dims are
    matched.  Dense-param engines thus skip the sparse-plan warmup even
    when cfg.sable is set."""
    want = {(p.n_tiles, p.tm, p.tk) for p in patterns.values()}
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        if (
            keys
            and keys[-1] in ("w1", "w2", "w3")
            and tuple(getattr(leaf, "shape", ())[-3:]) in want
        ):
            return True
    return False


def _pattern_plan_keys(pattern, mesh) -> list:
    """Every plan-cache key a deployment of ``pattern`` on this device
    touches: the base key plus per-shard keys when ``mesh`` has a shard
    axis (the scheduler checks the same set at admission)."""
    from ..core import cache as cachelib
    from ..sparse.linear import pattern_hash

    device = jax.default_backend()
    h = pattern_hash(pattern)
    keys = [cachelib.plan_key("linear", h, device)]
    if mesh is not None:
        from ..core.sharded import resolve_shard_axis

        try:
            axis = resolve_shard_axis(mesh, "shards")
        except ValueError:
            axis = None
        if axis is not None:
            n = int(mesh.shape[axis])
            keys += [
                cachelib.plan_key("linear", h, device, shard_id=i, num_shards=n)
                for i in range(n)
            ]
    return keys


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_len: int,
        enc_len: int = 0,
        autotune_sparse: bool = True,
        mesh=None,
        tune_mode: str = "measure",
    ):
        if tune_mode not in ("measure", "predict"):
            raise ValueError(f"unknown tune_mode {tune_mode!r}")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.enc_len = enc_len
        self.mesh = mesh
        self.tune_mode = tune_mode
        self.sparse_plans = {}
        self.patterns = ()
        self.warmup_stats = {"warm_start": True, "plans_staged": 0}
        if autotune_sparse and getattr(cfg, "sable", None) is not None:
            # Resolve sparse-matmul strategies BEFORE jit traces the model:
            # choose_matmul_strategy inside a trace can only fall back to the
            # device heuristic, while here it loads (or measures and
            # persists) the per-pattern plan from the shared plan cache.
            # With mesh= (1-D shards or 2-D shards x model) the per-shard
            # plans are warmed too, so a sharded deployment restarts with
            # zero re-benchmarks; a mesh with no shard axis (pure TP/DP)
            # warms the base plans only.
            from ..core import cache as cachelib
            from ..models.layers import sable_patterns
            from ..sparse.linear import warm_matmul_plans

            from ..core import cost_model as cmlib

            pats = sable_patterns(cfg)
            if _has_sparse_ffn(params, pats):
                self.patterns = tuple(pats.values())
                store = cachelib.default_cache()
                warm_start = all(
                    store.has_plan(k)
                    for p in self.patterns
                    for k in _pattern_plan_keys(p, mesh)
                )
                before = store.stats()["plans"]
                predicted_before = cmlib.cost_model_stats()["plans_predicted"]
                # warm-start restarts LOAD every plan (no measuring, no
                # re-staging — the restart-skips-work contract); a cold
                # start with tune_mode="measure" measures once and persists
                # for the next process, while tune_mode="predict" resolves
                # cold patterns from the learned cost model where it is
                # confident (measuring only the uncertain ones)
                self.sparse_plans = warm_matmul_plans(
                    self.patterns, mesh=mesh, mode=tune_mode
                )
                self.warmup_stats = {
                    "warm_start": warm_start,
                    "plans_staged": store.stats()["plans"] - before,
                    "plans_predicted": (
                        cmlib.cost_model_stats()["plans_predicted"]
                        - predicted_before
                    ),
                }
                assert not warm_start or self.warmup_stats["plans_staged"] == 0

        @jax.jit
        def _prefill(params, tokens, cache, enc_out):
            return prefill(params, cfg, tokens, cache, enc_out=enc_out)

        @functools.partial(jax.jit, donate_argnums=(2,))
        def _decode(params, tok, cache, pos, rng, temperature):
            logits, cache = decode_step(params, cfg, tok, cache, pos)
            logits = logits[:, 0].astype(jnp.float32)
            greedy = jnp.argmax(logits, axis=-1)
            sampled = jax.random.categorical(rng, logits / jnp.maximum(temperature, 1e-6))
            nxt = jnp.where(temperature > 0, sampled, greedy).astype(jnp.int32)
            return nxt[:, None], cache

        self._prefill = _prefill
        self._decode = _decode

    # ------------------------------------------------------------------ #
    # request-level serving (continuous batching over the paged cache)
    # ------------------------------------------------------------------ #
    def make_scheduler(self, *, max_len: Optional[int] = None, **kw):
        """A ContinuousBatchingScheduler sharing this engine's model and
        mesh.  kwargs pass through (page_size, num_pages, max_batch,
        policy, clock, plan_cache, record_logits, prefix_sharing,
        chunked_prefill, prefill_chunk, ...)."""
        from .scheduler import ContinuousBatchingScheduler

        return ContinuousBatchingScheduler(
            self.cfg,
            self.params,
            max_len=self.max_len if max_len is None else max_len,
            mesh=self.mesh,
            **kw,
        )

    def serve(self, requests, *, max_steps: int = 100_000, **kw):
        """Submit ``requests`` (dicts of ``submit`` kwargs) and run the
        scheduler to completion.  Returns ``(results, scheduler)`` where
        results maps rid -> {tokens, prompt_len, metrics, state}.

        Enc-dec configs can't use the paged scheduler (the paged cache
        pages self-attention KV only); instead of failing mid-submit the
        fallback is EXPLICIT: a once-per-process warning, ``paged: False``
        in ``warmup_stats``, and each request runs through ``generate()``
        (scheduler slot in the return is None).  Fallback request dicts
        accept an extra ``src_embeds`` entry ((S, d) or (1, S, d))."""
        if self.cfg.is_encdec:
            _warn_encdec_fallback()
            self.warmup_stats["paged"] = False
            return self._serve_fallback(requests), None
        self.warmup_stats["paged"] = True
        sched = self.make_scheduler(**kw)
        for r in requests:
            sched.submit(**r)
        results = sched.run(max_steps=max_steps)
        # surface page-sharing effectiveness next to the plan-warmup stats
        for k in ("prefix_hits", "pages_shared", "cow_copies"):
            self.warmup_stats[k] = sched.stats[k]
        return results, sched

    def _serve_fallback(self, requests) -> dict:
        """Sequential ``generate()`` execution with scheduler-shaped
        results (rid -> {tokens, prompt_len, metrics, state})."""
        results = {}
        for r in requests:
            r = dict(r)
            rid = r.pop("rid", None) or f"req{next(_FALLBACK_RID)}"
            prompt = np.asarray(r.pop("prompt"), np.int32).reshape(-1)
            src = r.pop("src_embeds", None)
            if src is not None:
                src = jnp.asarray(src)
                if src.ndim == 2:
                    src = src[None]
            out, stats = self.generate(
                jnp.asarray(prompt[None]),
                r.pop("max_new_tokens"),
                temperature=r.pop("temperature", 0.0),
                src_embeds=src,
                rng=r.pop("rng", None),
            )
            results[rid] = {
                "tokens": np.asarray(out[0]),
                "prompt_len": int(prompt.shape[0]),
                "metrics": {**stats, "fallback": "generate"},
                "state": "FINISHED",
            }
        return results

    # ------------------------------------------------------------------ #
    # single-batch compatibility shim (the numeric reference path)
    # ------------------------------------------------------------------ #
    def generate(
        self,
        prompts: jnp.ndarray,  # (B, P) int32
        max_new_tokens: int,
        temperature: float = 0.0,
        src_embeds: Optional[jnp.ndarray] = None,
        rng=None,
    ):
        cfg = self.cfg
        B, P = prompts.shape
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        enc_out = None
        if cfg.is_encdec:
            assert src_embeds is not None, "enc-dec serving needs src_embeds"
            enc_out = encode(self.params, cfg, src_embeds)
        cache = init_cache(
            cfg, B, self.max_len,
            enc_len=src_embeds.shape[1] if src_embeds is not None else 0,
        )
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, prompts, cache, enc_out)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)[
            :, None
        ].astype(jnp.int32)
        jax.block_until_ready(nxt)
        t1 = time.perf_counter()

        toks = [nxt]
        for i in range(max_new_tokens - 1):
            rng, sub = jax.random.split(rng)
            nxt, cache = self._decode(
                self.params, nxt, cache, jnp.int32(P + i), sub,
                jnp.float32(temperature),
            )
            toks.append(nxt)
        out = jnp.concatenate([prompts] + toks, axis=1)
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        stats = {
            "prefill_s": t1 - t0,
            "decode_s": t2 - t1,
            "tokens_per_s": B * max_new_tokens / max(t2 - t1, 1e-9),
        }
        return out, stats
