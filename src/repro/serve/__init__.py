from .engine import ServeEngine
from .paged_cache import PageAllocator, PagedKVCache, PagesExhausted
from .scheduler import ContinuousBatchingScheduler, Request
