from .engine import ServeEngine
from .paged_cache import PageAllocator, PagedKVCache
from .scheduler import ContinuousBatchingScheduler, Request
