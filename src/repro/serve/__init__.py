from .engine import ServeEngine
