"""Paged/blocked KV cache: a refcounted free-list page allocator over one
shared arena, with prefix sharing and copy-on-write.

The single-sequence engine preallocates a dense ``(B, max_len, ...)`` cache
per batch — fine for one request, wasteful for a server where prompt and
generation lengths are heterogeneous.  Here every attention cache leaf is
backed by ONE arena of fixed-size pages (``page_size`` token positions
each); a sequence owns ``ceil(len / page_size)`` pages through a per-
sequence page table and grows one page at a time mid-decode.  Pages are
recycled through a FIFO free list, so N concurrent requests share the
arena without per-request preallocation.

**Prefix sharing** (``prefix_sharing=True``) makes the page the unit of
reuse, not just of allocation: every fully-written prompt page is
registered in a prefix index keyed by the cumulative hash of the token
ids it covers, and ``alloc_seq`` maps a new request's page-aligned prompt
prefix onto already-resident pages with the same token history — the
request attaches under a per-page refcount instead of allocating and
re-prefilling.  Pages with refcount > 1 are immutable: any write goes
through copy-on-write (``_writable_page``), so divergent continuations
never corrupt a sibling's KV.  The index holds only *resident* pages
(entries drop when the last reference is released); sharing is therefore
exact — a hit means the bytes are already in the arena.

Leaf classification is structural, not name-based: two cache templates are
built with different ``s_max`` and every leaf whose shape changes carries a
sequence axis (GQA/MLA k/v) and is paged; shape-stable leaves (Mamba conv/
ssm state, cross-attention KV) are per-sequence *state* and stored whole.
This keeps the cache format-agnostic — a new mixer with a sequence axis is
paged automatically.  (Prefix sharing requires a fully-paged cache: state
leaves summarize the whole prompt and cannot be reconstructed from a
shared page span — the scheduler enforces this.)

Arenas are host (numpy) arrays: the scheduler gathers the active lanes
into a dense ``(repeat, B, S_view, ...)`` batch view per decode step (the
page-table indirection happens here, outside the jitted step) and scatters
each lane's newly written position back afterwards.  Page id
``num_pages`` is a reserved always-zero page used to pad the view for
lanes that have not allocated that far yet, so a gathered view is
bit-identical to the dense reference cache over every written position
and zero beyond it.

Eviction parks a sequence's *private* pages + state on the host and frees
them; pages shared with other sequences (refcount > 1) are retained under
the parked sequence's reference — they are already resident, so parking
copies nothing and frees nothing for them.  ``resume`` reallocates the
private pages and restores bit-for-bit, so a preempted sequence continues
decoding losslessly.  ``release_parked_shared`` demotes a parked
sequence's retained shared pages to host copies when the arena is under
terminal pressure.

Page-capacity failures raise the typed ``PagesExhausted`` (a
``RuntimeError`` subclass) so the scheduler can respond by evicting
instead of dying.
"""
from __future__ import annotations

import collections
import hashlib
import math
from typing import Dict, List, Optional

import numpy as np
import jax

from ..models.config import ModelConfig
from ..models.transformer import init_cache

__all__ = ["PageAllocator", "PagedKVCache", "PagesExhausted"]


class PagesExhausted(RuntimeError):
    """A write needed a page the allocator could not provide.  The cache
    state is consistent (the failed operation wrote nothing past its last
    completed page); the scheduler handles this by evicting per policy and
    retrying, instead of the step dying on a bare RuntimeError."""


class PageAllocator:
    """FIFO free-list page allocator.  Deterministic: pages are handed out
    in ascending id order initially and recycled in free order, so a fixed
    request sequence always produces the same page tables (the golden
    serving fixture freezes exactly this)."""

    def __init__(self, num_pages: int):
        if num_pages < 1:
            raise ValueError("need at least one page")
        self.num_pages = int(num_pages)
        self._free = collections.deque(range(self.num_pages))
        self._held: set = set()
        self.total_allocated = 0  # cumulative pages handed out (bench)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_held(self) -> int:
        return len(self._held)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages atomically; None (state unchanged) if the
        free list is short."""
        if n < 0:
            raise ValueError("negative allocation")
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        self._held.update(pages)
        self.total_allocated += n
        return pages

    def free(self, pages) -> None:
        for p in pages:
            if p not in self._held:
                raise ValueError(f"double free / foreign page {p}")
            self._held.discard(p)
            self._free.append(p)

    def check(self) -> None:
        """Invariant: every page is exactly once free or held."""
        assert len(self._free) + len(self._held) == self.num_pages
        assert set(self._free) | self._held == set(range(self.num_pages))
        assert not (set(self._free) & self._held)


def _flatten(tree):
    return jax.tree_util.tree_flatten(tree)


class PagedKVCache:
    """Model-shaped paged cache arena (see module docstring).

    Parameters
    ----------
    cfg : ModelConfig (decoder-only; enc-dec goes through the legacy path)
    num_pages : total allocatable pages shared by all sequences
    page_size : token positions per page
    max_len : per-sequence logical capacity; the dense batch view is
        ``view_pages * page_size`` wide with ``view_pages =
        ceil(max_len / page_size)``
    prefix_sharing : maintain the prefix index so ``alloc_seq(tokens=...)``
        attaches to resident pages with the same token prefix (COW on
        write); off by default — the golden serving transcript pins the
        unshared schedule
    """

    def __init__(
        self,
        cfg: ModelConfig,
        num_pages: int,
        page_size: int,
        max_len: int,
        dtype=None,
        prefix_sharing: bool = False,
    ):
        if cfg.is_encdec:
            raise ValueError(
                "PagedKVCache is decoder-only; enc-dec serving uses the "
                "single-sequence compatibility path"
            )
        self.cfg = cfg
        self.page_size = int(page_size)
        self.max_len = int(max_len)
        self.prefix_sharing = bool(prefix_sharing)
        self.view_pages = math.ceil(self.max_len / self.page_size)
        if num_pages < self.view_pages:
            raise ValueError(
                f"num_pages={num_pages} cannot hold even one max_len="
                f"{max_len} sequence ({self.view_pages} pages needed)"
            )
        self.allocator = PageAllocator(num_pages)
        self.zero_page = num_pages  # reserved, always zero, never allocated

        # structural classification: leaves whose shape varies with s_max
        # carry the sequence axis (paged); the rest are per-seq state
        ta, _ = _flatten(init_cache(cfg, 1, 2, dtype=dtype))
        tb, self.treedef = _flatten(init_cache(cfg, 1, 3, dtype=dtype))
        self.num_leaves = len(tb)
        self.paged: List[bool] = []
        self.seq_axis: List[Optional[int]] = []
        self._arenas: List[Optional[np.ndarray]] = []
        self._state_shape: List[Optional[tuple]] = []
        self._dtypes = []
        for la, lb in zip(ta, tb):
            self._dtypes.append(np.dtype(lb.dtype))
            if la.shape != lb.shape:
                diffs = [
                    i for i, (x, y) in enumerate(zip(la.shape, lb.shape)) if x != y
                ]
                assert diffs == [2], (
                    f"expected a single seq axis at 2, got {diffs} for "
                    f"{la.shape} vs {lb.shape}"
                )
                self.paged.append(True)
                self.seq_axis.append(2)
                feat = tuple(lb.shape[3:])
                repeat = lb.shape[0]
                self._arenas.append(
                    np.zeros(
                        (num_pages + 1, repeat, self.page_size) + feat,
                        np.dtype(lb.dtype),
                    )
                )
                self._state_shape.append(None)
            else:
                self.paged.append(False)
                self.seq_axis.append(None)
                self._arenas.append(None)
                self._state_shape.append(tuple(lb.shape))

        # per-sequence bookkeeping
        self.page_table: Dict[str, List[int]] = {}
        self.seq_len: Dict[str, int] = {}
        self._state: Dict[str, List[Optional[np.ndarray]]] = {}
        self._parked: Dict[str, dict] = {}

        # refcounts + prefix index (page -> owners; digest <-> page)
        self._ref: Dict[int, int] = {}
        self._prefix_index: Dict[bytes, int] = {}
        self._page_digest: Dict[int, bytes] = {}
        # per-seq prompt digests + share cap (last-token page never shared)
        self._share_info: Dict[str, dict] = {}
        self._hit_rids: set = set()
        self.share_stats = {
            "prefix_hits": 0,
            "pages_shared": 0,
            "cow_copies": 0,
        }
        self.zero_writes = 0  # pages zeroed (prefill-path bandwidth audit)

    # ------------------------------------------------------------------ #
    # mask pytree for the lane decoder (True = leaf has a sequence axis)
    # ------------------------------------------------------------------ #
    @property
    def paged_mask(self):
        return jax.tree_util.tree_unflatten(self.treedef, list(self.paged))

    # ------------------------------------------------------------------ #
    # allocation
    # ------------------------------------------------------------------ #
    def pages_needed(self, n_tokens: int) -> int:
        # n_tokens == 0 needs 0 pages (a former max(1, ...) here made
        # zero-token allocations hold a page forever)
        return math.ceil(n_tokens / self.page_size)

    def can_alloc(self, n_tokens: int) -> bool:
        return self.allocator.num_free >= self.pages_needed(n_tokens)

    def _digests(self, tokens) -> List[bytes]:
        """Cumulative blake2b digest per full ``page_size`` token chunk:
        digest j identifies tokens[0 : (j+1)*page_size] — a page is only
        reusable when its entire token history matches."""
        toks = np.ascontiguousarray(np.asarray(tokens, np.int64).reshape(-1))
        h = hashlib.blake2b(str(self.page_size).encode(), digest_size=16)
        out = []
        for j in range(len(toks) // self.page_size):
            h.update(toks[j * self.page_size : (j + 1) * self.page_size].tobytes())
            out.append(h.copy().digest())
        return out

    def alloc_seq(
        self,
        rid: str,
        n_tokens: int,
        tokens=None,
        *,
        reserve: Optional[int] = None,
        zero: bool = True,
    ) -> bool:
        """Reserve pages for ``n_tokens`` positions and zero-init state.
        False (nothing changes) if the free list is short.

        With ``prefix_sharing`` on and the prompt ``tokens`` given, full
        pages whose cumulative token hash is already in the prefix index
        are attached by reference instead of allocated — ``seq_len[rid]``
        comes back equal to the shared span (the caller prefills only the
        tail; the last prompt token is never shared so its logits are
        always computed).

        ``reserve`` caps the initial page reservation to cover only
        ``reserve`` tokens (chunked prefill admits with the first chunk's
        pages, growing per chunk); default reserves the full ``n_tokens``.
        ``zero=False`` skips zero-initializing the fresh pages — only for
        callers that immediately overwrite every reserved page
        (``write_prefill`` / ``write_span`` zero the written span's tail
        themselves)."""
        if rid in self.page_table:
            raise ValueError(f"sequence {rid!r} already allocated")
        if n_tokens > self.max_len:
            raise ValueError(f"{n_tokens} tokens > max_len={self.max_len}")

        shared: List[int] = []
        digests: List[bytes] = []
        if self.prefix_sharing and tokens is not None and n_tokens > 1:
            digests = self._digests(tokens)
            # never share the page holding the last prompt token: its
            # logits seed the first generated token and must be computed
            cap = (n_tokens - 1) // self.page_size
            for j in range(min(cap, len(digests))):
                page = self._prefix_index.get(digests[j])
                if page is None:
                    break
                shared.append(page)

        target = max(n_tokens if reserve is None else min(reserve, n_tokens),
                     len(shared) * self.page_size)
        fresh = self.allocator.alloc(self.pages_needed(target) - len(shared))
        if fresh is None:
            return False
        for p in shared:
            self._ref[p] += 1
        for p in fresh:
            self._ref[p] = 1
            if zero:
                self._zero_page(p)
        self.page_table[rid] = shared + fresh
        self.seq_len[rid] = len(shared) * self.page_size
        self._state[rid] = [
            None if s is None else np.zeros(s, self._dtypes[i])
            for i, s in enumerate(self._state_shape)
        ]
        if digests:
            self._share_info[rid] = {"digests": digests, "cap": cap}
        if shared:
            if rid not in self._hit_rids:
                self._hit_rids.add(rid)
                self.share_stats["prefix_hits"] += 1
            self.share_stats["pages_shared"] += len(shared)
        return True

    def attach_shared(self, rid: str) -> int:
        """Late prefix attachment for chunked prefill: requests admitted in
        the same step as the prefix's first writer find the index empty at
        ``alloc_seq`` — so a mid-prefill sequence re-probes before each
        chunk and swaps its next (page-aligned, unwritten) slots for index
        pages that have since become resident, skipping those chunks'
        compute.  Returns the token positions newly covered."""
        info = self._share_info.get(rid)
        if info is None or rid not in self.page_table:
            return 0
        ps = self.page_size
        attached = 0
        while True:
            sl = self.seq_len[rid]
            if sl % ps:
                break  # mid-page frontier (unaligned chunk): can't attach
            j = sl // ps
            if j >= info["cap"] or j >= len(info["digests"]):
                break
            d = info["digests"][j]
            page = None if d is None else self._prefix_index.get(d)
            if page is None:
                break
            pt = self.page_table[rid]
            if j < len(pt):
                # slot was reserved with a fresh private page that nothing
                # has written yet (seq_len <= j*ps): swap it for the
                # shared one and return it to the pool
                self._decref(pt[j])
                pt[j] = page
            else:
                pt.append(page)
            self._ref[page] += 1
            self.seq_len[rid] = sl + ps
            attached += 1
        if attached:
            if rid not in self._hit_rids:
                self._hit_rids.add(rid)
                self.share_stats["prefix_hits"] += 1
            self.share_stats["pages_shared"] += attached
        return attached * ps

    def shared_prefix_len(self, rid: str) -> int:
        """Token positions of ``rid`` attached from the prefix index at
        ``alloc_seq`` time (== initial ``seq_len``)."""
        pt = self.page_table[rid]
        return self.page_size * sum(1 for p in pt if self._ref[p] > 1)

    def ensure_capacity(self, rid: str, n_tokens: int, *, zero: bool = True) -> bool:
        """Grow the page table to cover ``n_tokens`` positions."""
        need = self.pages_needed(n_tokens) - len(self.page_table[rid])
        if need <= 0:
            return True
        pages = self.allocator.alloc(need)
        if pages is None:
            return False
        for p in pages:
            self._ref[p] = 1
            if zero:
                self._zero_page(p)
        self.page_table[rid].extend(pages)
        return True

    def free_seq(self, rid: str) -> None:
        """Release ``rid`` — live or parked.  A parked sequence (finish /
        cancel while preempted) drops its host copies and releases the
        shared pages it retained; this is the path that must never
        double-free (the allocator's check would catch it)."""
        if rid in self._parked:
            park = self._parked.pop(rid)
            for slot in park["slots"]:
                if slot["page"] is not None:
                    self._decref(slot["page"])
        else:
            for p in self.page_table.pop(rid):
                self._decref(p)
        self.seq_len.pop(rid, None)
        self._state.pop(rid, None)
        self._share_info.pop(rid, None)
        self._hit_rids.discard(rid)

    def _decref(self, page: int) -> None:
        r = self._ref[page] - 1
        if r > 0:
            self._ref[page] = r
            return
        del self._ref[page]
        self._deregister(page)
        self.allocator.free([page])

    def _zero_page(self, page: int) -> None:
        # recycled pages may hold a dead sequence's KV; zeroing keeps every
        # gathered view bit-identical to the dense reference cache
        self.zero_writes += 1
        for a in self._arenas:
            if a is not None:
                a[page] = 0

    # ------------------------------------------------------------------ #
    # prefix index
    # ------------------------------------------------------------------ #
    def _register(self, rid: str) -> None:
        """Advertise ``rid``'s fully-written full prompt pages in the
        prefix index (first writer wins)."""
        info = self._share_info.get(rid)
        if info is None:
            return
        digests = info["digests"]
        pt = self.page_table[rid]
        n_full = min(self.seq_len[rid] // self.page_size, len(digests), len(pt))
        for j in range(n_full):
            d = digests[j]
            if d is None or d in self._prefix_index:
                continue
            page = pt[j]
            if page in self._page_digest:
                continue
            self._prefix_index[d] = page
            self._page_digest[page] = d

    def _deregister(self, page: int) -> None:
        d = self._page_digest.pop(page, None)
        if d is not None:
            self._prefix_index.pop(d, None)

    def _mark_overwritten(self, rid: str, start: int, end: int) -> None:
        """A write below the frontier mutates prompt pages away from their
        token digests: void those slots' digests for this sequence so a
        later ``_register`` can never advertise the mutated content."""
        old_len = self.seq_len[rid]
        if start >= old_len:
            return
        info = self._share_info.get(rid)
        if info is None:
            return
        ps = self.page_size
        for j in range(start // ps, min((end - 1) // ps + 1, len(info["digests"]))):
            info["digests"][j] = None

    def _writable_page(self, rid: str, j: int) -> int:
        """Page backing slot ``j`` of ``rid``, made safe to write: shared
        pages (refcount > 1) are copied first (COW) so siblings keep the
        original bytes; a sole-owned page still advertised in the prefix
        index is deregistered (its content is about to change)."""
        pt = self.page_table[rid]
        page = pt[j]
        if self._ref[page] > 1:
            got = self.allocator.alloc(1)
            if got is None:
                raise PagesExhausted(
                    f"copy-on-write for {rid!r} page slot {j}: no free pages"
                )
            new = got[0]
            for a in self._arenas:
                if a is not None:
                    a[new] = a[page]
            self._ref[new] = 1
            self._ref[page] -= 1
            pt[j] = new
            self.share_stats["cow_copies"] += 1
            return new
        self._deregister(page)
        return page

    # ------------------------------------------------------------------ #
    # writes
    # ------------------------------------------------------------------ #
    def write_prefill(self, rid: str, cache, length: int, start: int = 0) -> None:
        """Copy a dense single-sequence cache (leaves ``(repeat, 1, S, ...)``
        with ``S >= length``) into this sequence's pages + state.  With
        ``start > 0`` only positions ``[start, length)`` are written —
        chunked prefill and the tail after a shared prefix."""
        self.write_span(rid, cache, start, length)

    def write_span(self, rid: str, cache, start: int, end: int) -> None:
        """Write positions ``[start, end)`` from a dense cache into pages;
        state leaves are replaced wholesale.  Grows the page table to cover
        ``end`` (unzeroed — every grown page is covered by this write plus
        the explicit tail zero), raising ``PagesExhausted`` when it can't."""
        if not self.ensure_capacity(rid, end, zero=False):
            raise PagesExhausted(f"no pages for prefill of {rid!r}")
        self._mark_overwritten(rid, start, end)
        leaves, _ = _flatten(cache)
        assert len(leaves) == self.num_leaves
        ps = self.page_size
        old_len = self.seq_len[rid]
        j0, j1 = start // ps, (max(end, start + 1) - 1) // ps
        pages = [self._writable_page(rid, j) for j in range(j0, j1 + 1)]
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            if self.paged[i]:
                for j, page in zip(range(j0, j1 + 1), pages):
                    lo, hi = max(j * ps, start), min((j + 1) * ps, end)
                    if hi <= lo:
                        continue
                    self._arenas[i][page, :, lo - j * ps : hi - j * ps] = (
                        arr[:, 0, lo:hi]
                    )
                # the frontier page may be fresh (allocated unzeroed by the
                # ensure_capacity above): zero the not-yet-written tail so
                # gathered views stay bit-identical to the dense reference
                if end >= old_len and end % ps:
                    self._arenas[i][pages[-1], :, end % ps :] = 0
            else:
                self._state[rid][i] = arr.copy()
        self.seq_len[rid] = max(old_len, end)
        if self.prefix_sharing:
            self._register(rid)

    def append_token(self, rid: str, slices, position: int) -> None:
        """Write one decode step's output for one lane: ``slices`` is a
        flat leaf list — paged leaves ``(repeat, ...feat)`` (the KV written
        at ``position``, batch/seq axes squeezed), state leaves
        ``(repeat, 1, ...)`` replace the stored state wholesale."""
        if not self.ensure_capacity(rid, position + 1):
            raise PagesExhausted(f"no pages to append to {rid!r}")
        self._mark_overwritten(rid, position, position + 1)
        page = self._writable_page(rid, position // self.page_size)
        off = position % self.page_size
        for i, leaf in enumerate(slices):
            arr = np.asarray(leaf)
            if self.paged[i]:
                self._arenas[i][page, :, off] = arr
            else:
                self._state[rid][i] = arr.copy()
        self.seq_len[rid] = max(self.seq_len[rid], position + 1)

    # ------------------------------------------------------------------ #
    # reads
    # ------------------------------------------------------------------ #
    def _full_table(self, rid: Optional[str]) -> List[int]:
        pt = [] if rid is None else self.page_table[rid]
        return list(pt) + [self.zero_page] * (self.view_pages - len(pt))

    def gather(self, rids: List[Optional[str]]):
        """Materialize the dense batch view for a list of lanes (None =
        empty lane, all zeros).  Leaves come back shaped like
        ``init_cache(cfg, B, view_pages * page_size)``."""
        B = len(rids)
        tables = np.asarray([self._full_table(r) for r in rids], np.int64)
        leaves = []
        for i in range(self.num_leaves):
            if self.paged[i]:
                a = self._arenas[i][tables]  # (B, VP, repeat, ps, ...feat)
                a = np.moveaxis(a, 2, 0)  # (repeat, B, VP, ps, ...)
                leaves.append(
                    a.reshape(a.shape[:2] + (-1,) + a.shape[4:])
                )
            else:
                zero = np.zeros(self._state_shape[i], self._dtypes[i])
                leaves.append(
                    np.concatenate(
                        [
                            (zero if r is None else self._state[r][i])
                            for r in rids
                        ],
                        axis=1,
                    )
                    if B
                    else zero
                )
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def read_dense(self, rid: str, s_max: Optional[int] = None):
        """Dense single-sequence cache for ``rid`` — shaped like
        ``init_cache(cfg, 1, s_max)`` with every written position equal to
        the page contents bit-for-bit (the property-test contract)."""
        length = self.seq_len[rid]
        s_max = length if s_max is None else s_max
        if s_max < length:
            raise ValueError("s_max shorter than written length")
        ps = self.page_size
        leaves = []
        for i in range(self.num_leaves):
            if self.paged[i]:
                a = self._arenas[i]
                repeat, feat = a.shape[1], a.shape[3:]
                out = np.zeros(
                    (repeat, 1, s_max) + feat, self._dtypes[i]
                )
                for j, page in enumerate(self.page_table[rid]):
                    w = min(ps, length - j * ps)
                    if w <= 0:
                        break
                    out[:, 0, j * ps : j * ps + w] = a[page, :, :w]
                leaves.append(out)
            else:
                leaves.append(self._state[rid][i].copy())
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # ------------------------------------------------------------------ #
    # eviction / resume (lossless preemption)
    # ------------------------------------------------------------------ #
    def evict(self, rid: str) -> None:
        """Park ``rid``: private pages (refcount 1) are copied to the host
        and freed; shared pages stay resident under this sequence's
        reference (parking copies and frees nothing for them — the prefix
        span survives for siblings and for our own resume)."""
        pt = self.page_table.pop(rid)
        slots = []
        for p in pt:
            if self._ref[p] > 1:
                slots.append({"page": p, "blobs": None})
            else:
                slots.append({
                    "page": None,
                    "blobs": [
                        None if a is None else a[p].copy()
                        for a in self._arenas
                    ],
                })
                self._decref(p)
        self._parked[rid] = {
            "slots": slots,
            "state": [
                None if s is None else s.copy() for s in self._state.pop(rid)
            ],
            "seq_len": self.seq_len.pop(rid),
        }

    def resume(self, rid: str) -> bool:
        """Re-own pages for a parked sequence and restore its contents
        bit-for-bit: retained shared pages re-attach in place (their bytes
        never changed — writers COW away), private pages reallocate and
        refill.  False (still parked, nothing changes) if pages are short."""
        park = self._parked[rid]
        private = [j for j, s in enumerate(park["slots"]) if s["page"] is None]
        pages = self.allocator.alloc(len(private))
        if pages is None:
            return False
        table: List[int] = []
        it = iter(pages)
        for slot in park["slots"]:
            if slot["page"] is not None:
                table.append(slot["page"])  # ref was retained at evict
                continue
            p = next(it)
            self._ref[p] = 1
            for a, blob in zip(self._arenas, slot["blobs"]):
                if a is not None:
                    a[p] = blob
            table.append(p)
        self.page_table[rid] = table
        self.seq_len[rid] = park["seq_len"]
        self._state[rid] = park["state"]
        del self._parked[rid]
        return True

    def is_parked(self, rid: str) -> bool:
        return rid in self._parked

    def parked_shared_pages(self, rid: str) -> int:
        """Pages a parked ``rid`` still holds resident by reference."""
        return sum(
            1 for s in self._parked[rid]["slots"] if s["page"] is not None
        )

    def release_parked_shared(self, rid: str) -> int:
        """Demote a parked sequence's retained shared pages to host copies,
        dropping its references (pages whose refcount hits zero free).
        Lossless — resume re-allocates them like any private page.  Returns
        the number of references released (the terminal-pressure escape
        valve: without it, parked siblings could pin the arena)."""
        released = 0
        for slot in self._parked[rid]["slots"]:
            page = slot["page"]
            if page is None:
                continue
            slot["blobs"] = [
                None if a is None else a[page].copy() for a in self._arenas
            ]
            slot["page"] = None
            self._decref(page)
            released += 1
        return released

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        return {
            "num_pages": self.allocator.num_pages,
            "free_pages": self.allocator.num_free,
            "held_pages": self.allocator.num_held,
            "pages_allocated_total": self.allocator.total_allocated,
            "page_size": self.page_size,
            "view_pages": self.view_pages,
            "sequences": len(self.page_table),
            "parked": len(self._parked),
            "indexed_prefix_pages": len(self._prefix_index),
            "shared_pages_now": sum(1 for r in self._ref.values() if r > 1),
            "zero_writes": self.zero_writes,
            **self.share_stats,
        }
