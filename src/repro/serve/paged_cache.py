"""Paged/blocked KV cache: a free-list page allocator over one shared arena.

The single-sequence engine preallocates a dense ``(B, max_len, ...)`` cache
per batch — fine for one request, wasteful for a server where prompt and
generation lengths are heterogeneous.  Here every attention cache leaf is
backed by ONE arena of fixed-size pages (``page_size`` token positions
each); a sequence owns ``ceil(len / page_size)`` pages through a per-
sequence page table and grows one page at a time mid-decode.  Pages are
recycled through a FIFO free list, so N concurrent requests share the
arena without per-request preallocation.

Leaf classification is structural, not name-based: two cache templates are
built with different ``s_max`` and every leaf whose shape changes carries a
sequence axis (GQA/MLA k/v) and is paged; shape-stable leaves (Mamba conv/
ssm state, cross-attention KV) are per-sequence *state* and stored whole.
This keeps the cache format-agnostic — a new mixer with a sequence axis is
paged automatically.

Arenas are host (numpy) arrays: the scheduler gathers the active lanes
into a dense ``(repeat, B, S_view, ...)`` batch view per decode step (the
page-table indirection happens here, outside the jitted step) and scatters
each lane's newly written position back afterwards.  Page id
``num_pages`` is a reserved always-zero page used to pad the view for
lanes that have not allocated that far yet, so a gathered view is
bit-identical to the dense reference cache over every written position
and zero beyond it.

Eviction parks a sequence's pages + state on the host (``evict``) and
frees the pages; ``resume`` reallocates and restores bit-for-bit, so a
preempted sequence continues decoding losslessly.
"""
from __future__ import annotations

import collections
import math
from typing import Dict, List, Optional

import numpy as np
import jax

from ..models.config import ModelConfig
from ..models.transformer import init_cache

__all__ = ["PageAllocator", "PagedKVCache"]


class PageAllocator:
    """FIFO free-list page allocator.  Deterministic: pages are handed out
    in ascending id order initially and recycled in free order, so a fixed
    request sequence always produces the same page tables (the golden
    serving fixture freezes exactly this)."""

    def __init__(self, num_pages: int):
        if num_pages < 1:
            raise ValueError("need at least one page")
        self.num_pages = int(num_pages)
        self._free = collections.deque(range(self.num_pages))
        self._held: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_held(self) -> int:
        return len(self._held)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages atomically; None (state unchanged) if the
        free list is short."""
        if n < 0:
            raise ValueError("negative allocation")
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        self._held.update(pages)
        return pages

    def free(self, pages) -> None:
        for p in pages:
            if p not in self._held:
                raise ValueError(f"double free / foreign page {p}")
            self._held.discard(p)
            self._free.append(p)

    def check(self) -> None:
        """Invariant: every page is exactly once free or held."""
        assert len(self._free) + len(self._held) == self.num_pages
        assert set(self._free) | self._held == set(range(self.num_pages))
        assert not (set(self._free) & self._held)


def _flatten(tree):
    return jax.tree_util.tree_flatten(tree)


class PagedKVCache:
    """Model-shaped paged cache arena (see module docstring).

    Parameters
    ----------
    cfg : ModelConfig (decoder-only; enc-dec goes through the legacy path)
    num_pages : total allocatable pages shared by all sequences
    page_size : token positions per page
    max_len : per-sequence logical capacity; the dense batch view is
        ``view_pages * page_size`` wide with ``view_pages =
        ceil(max_len / page_size)``
    """

    def __init__(
        self,
        cfg: ModelConfig,
        num_pages: int,
        page_size: int,
        max_len: int,
        dtype=None,
    ):
        if cfg.is_encdec:
            raise ValueError(
                "PagedKVCache is decoder-only; enc-dec serving uses the "
                "single-sequence compatibility path"
            )
        self.cfg = cfg
        self.page_size = int(page_size)
        self.max_len = int(max_len)
        self.view_pages = math.ceil(self.max_len / self.page_size)
        if num_pages < self.view_pages:
            raise ValueError(
                f"num_pages={num_pages} cannot hold even one max_len="
                f"{max_len} sequence ({self.view_pages} pages needed)"
            )
        self.allocator = PageAllocator(num_pages)
        self.zero_page = num_pages  # reserved, always zero, never allocated

        # structural classification: leaves whose shape varies with s_max
        # carry the sequence axis (paged); the rest are per-seq state
        ta, _ = _flatten(init_cache(cfg, 1, 2, dtype=dtype))
        tb, self.treedef = _flatten(init_cache(cfg, 1, 3, dtype=dtype))
        self.num_leaves = len(tb)
        self.paged: List[bool] = []
        self.seq_axis: List[Optional[int]] = []
        self._arenas: List[Optional[np.ndarray]] = []
        self._state_shape: List[Optional[tuple]] = []
        self._dtypes = []
        for la, lb in zip(ta, tb):
            self._dtypes.append(np.dtype(lb.dtype))
            if la.shape != lb.shape:
                diffs = [
                    i for i, (x, y) in enumerate(zip(la.shape, lb.shape)) if x != y
                ]
                assert diffs == [2], (
                    f"expected a single seq axis at 2, got {diffs} for "
                    f"{la.shape} vs {lb.shape}"
                )
                self.paged.append(True)
                self.seq_axis.append(2)
                feat = tuple(lb.shape[3:])
                repeat = lb.shape[0]
                self._arenas.append(
                    np.zeros(
                        (num_pages + 1, repeat, self.page_size) + feat,
                        np.dtype(lb.dtype),
                    )
                )
                self._state_shape.append(None)
            else:
                self.paged.append(False)
                self.seq_axis.append(None)
                self._arenas.append(None)
                self._state_shape.append(tuple(lb.shape))

        # per-sequence bookkeeping
        self.page_table: Dict[str, List[int]] = {}
        self.seq_len: Dict[str, int] = {}
        self._state: Dict[str, List[Optional[np.ndarray]]] = {}
        self._parked: Dict[str, dict] = {}

    # ------------------------------------------------------------------ #
    # mask pytree for the lane decoder (True = leaf has a sequence axis)
    # ------------------------------------------------------------------ #
    @property
    def paged_mask(self):
        return jax.tree_util.tree_unflatten(self.treedef, list(self.paged))

    # ------------------------------------------------------------------ #
    # allocation
    # ------------------------------------------------------------------ #
    def pages_needed(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.page_size))

    def can_alloc(self, n_tokens: int) -> bool:
        return self.allocator.num_free >= self.pages_needed(n_tokens)

    def alloc_seq(self, rid: str, n_tokens: int) -> bool:
        """Reserve pages for ``n_tokens`` positions and zero-init state.
        False (nothing changes) if the free list is short."""
        if rid in self.page_table:
            raise ValueError(f"sequence {rid!r} already allocated")
        if n_tokens > self.max_len:
            raise ValueError(f"{n_tokens} tokens > max_len={self.max_len}")
        pages = self.allocator.alloc(self.pages_needed(n_tokens))
        if pages is None:
            return False
        for p in pages:
            self._zero_page(p)
        self.page_table[rid] = pages
        self.seq_len[rid] = 0
        self._state[rid] = [
            None if s is None else np.zeros(s, self._dtypes[i])
            for i, s in enumerate(self._state_shape)
        ]
        return True

    def ensure_capacity(self, rid: str, n_tokens: int) -> bool:
        """Grow the page table to cover ``n_tokens`` positions."""
        need = self.pages_needed(n_tokens) - len(self.page_table[rid])
        if need <= 0:
            return True
        pages = self.allocator.alloc(need)
        if pages is None:
            return False
        for p in pages:
            self._zero_page(p)
        self.page_table[rid].extend(pages)
        return True

    def free_seq(self, rid: str) -> None:
        self.allocator.free(self.page_table.pop(rid))
        self.seq_len.pop(rid, None)
        self._state.pop(rid, None)

    def _zero_page(self, page: int) -> None:
        # recycled pages may hold a dead sequence's KV; zeroing keeps every
        # gathered view bit-identical to the dense reference cache
        for a in self._arenas:
            if a is not None:
                a[page] = 0

    # ------------------------------------------------------------------ #
    # writes
    # ------------------------------------------------------------------ #
    def write_prefill(self, rid: str, cache, length: int) -> None:
        """Copy a dense single-sequence cache (leaves ``(repeat, 1, S, ...)``
        with ``S >= length``) into this sequence's pages + state."""
        if not self.ensure_capacity(rid, length):
            raise RuntimeError(f"no pages for prefill of {rid!r}")
        leaves, _ = _flatten(cache)
        assert len(leaves) == self.num_leaves
        pt = self.page_table[rid]
        ps = self.page_size
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            if self.paged[i]:
                for j in range(self.pages_needed(length)):
                    w = min(ps, length - j * ps)
                    if w <= 0:
                        break
                    self._arenas[i][pt[j], :, :w] = arr[:, 0, j * ps : j * ps + w]
            else:
                self._state[rid][i] = arr.copy()
        self.seq_len[rid] = length

    def append_token(self, rid: str, slices, position: int) -> None:
        """Write one decode step's output for one lane: ``slices`` is a
        flat leaf list — paged leaves ``(repeat, ...feat)`` (the KV written
        at ``position``, batch/seq axes squeezed), state leaves
        ``(repeat, 1, ...)`` replace the stored state wholesale."""
        if not self.ensure_capacity(rid, position + 1):
            raise RuntimeError(f"no pages to append to {rid!r}")
        page = self.page_table[rid][position // self.page_size]
        off = position % self.page_size
        for i, leaf in enumerate(slices):
            arr = np.asarray(leaf)
            if self.paged[i]:
                self._arenas[i][page, :, off] = arr
            else:
                self._state[rid][i] = arr.copy()
        self.seq_len[rid] = max(self.seq_len[rid], position + 1)

    # ------------------------------------------------------------------ #
    # reads
    # ------------------------------------------------------------------ #
    def _full_table(self, rid: Optional[str]) -> List[int]:
        pt = [] if rid is None else self.page_table[rid]
        return list(pt) + [self.zero_page] * (self.view_pages - len(pt))

    def gather(self, rids: List[Optional[str]]):
        """Materialize the dense batch view for a list of lanes (None =
        empty lane, all zeros).  Leaves come back shaped like
        ``init_cache(cfg, B, view_pages * page_size)``."""
        B = len(rids)
        tables = np.asarray([self._full_table(r) for r in rids], np.int64)
        leaves = []
        for i in range(self.num_leaves):
            if self.paged[i]:
                a = self._arenas[i][tables]  # (B, VP, repeat, ps, ...feat)
                a = np.moveaxis(a, 2, 0)  # (repeat, B, VP, ps, ...)
                leaves.append(
                    a.reshape(a.shape[:2] + (-1,) + a.shape[4:])
                )
            else:
                zero = np.zeros(self._state_shape[i], self._dtypes[i])
                leaves.append(
                    np.concatenate(
                        [
                            (zero if r is None else self._state[r][i])
                            for r in rids
                        ],
                        axis=1,
                    )
                    if B
                    else zero
                )
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def read_dense(self, rid: str, s_max: Optional[int] = None):
        """Dense single-sequence cache for ``rid`` — shaped like
        ``init_cache(cfg, 1, s_max)`` with every written position equal to
        the page contents bit-for-bit (the property-test contract)."""
        length = self.seq_len[rid]
        s_max = length if s_max is None else s_max
        if s_max < length:
            raise ValueError("s_max shorter than written length")
        ps = self.page_size
        leaves = []
        for i in range(self.num_leaves):
            if self.paged[i]:
                a = self._arenas[i]
                repeat, feat = a.shape[1], a.shape[3:]
                out = np.zeros(
                    (repeat, 1, s_max) + feat, self._dtypes[i]
                )
                for j, page in enumerate(self.page_table[rid]):
                    w = min(ps, length - j * ps)
                    if w <= 0:
                        break
                    out[:, 0, j * ps : j * ps + w] = a[page, :, :w]
                leaves.append(out)
            else:
                leaves.append(self._state[rid][i].copy())
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # ------------------------------------------------------------------ #
    # eviction / resume (lossless preemption)
    # ------------------------------------------------------------------ #
    def evict(self, rid: str) -> None:
        """Park ``rid``'s pages + state on the host and free the pages."""
        length = self.seq_len[rid]
        pt = self.page_table[rid]
        parked_pages = [
            None
            if a is None
            else a[pt].copy()  # (n_pages, repeat, ps, ...feat)
            for a in self._arenas
        ]
        self._parked[rid] = {
            "pages": parked_pages,
            "n_pages": len(pt),
            "state": [
                None if s is None else s.copy() for s in self._state[rid]
            ],
            "seq_len": length,
        }
        self.free_seq(rid)

    def resume(self, rid: str) -> bool:
        """Reallocate pages for a parked sequence and restore its contents
        bit-for-bit.  False (still parked) if pages are short."""
        park = self._parked[rid]
        pages = self.allocator.alloc(park["n_pages"])
        if pages is None:
            return False
        for i, blob in enumerate(park["pages"]):
            if blob is not None:
                self._arenas[i][pages] = blob
        self.page_table[rid] = pages
        self.seq_len[rid] = park["seq_len"]
        self._state[rid] = park["state"]
        del self._parked[rid]
        return True

    def is_parked(self, rid: str) -> bool:
        return rid in self._parked

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        return {
            "num_pages": self.allocator.num_pages,
            "free_pages": self.allocator.num_free,
            "held_pages": self.allocator.num_held,
            "page_size": self.page_size,
            "view_pages": self.view_pages,
            "sequences": len(self.page_table),
            "parked": len(self._parked),
        }
