"""Sharding rules: DP / FSDP(ZeRO) / TP / EP / SP over the production mesh.

Axes:
  dp axes      ("pod", "data")  — batch (data parallel)
  fsdp axes    ("data",) default, optionally +("pod",) — parameter and
               optimizer-state sharding (ZeRO-3); all-gathered per scan step
  tensor axis  "model"          — Megatron-style TP, MoE expert parallelism,
               and sequence/context-parallel KV caches when head counts
               don't divide the axis

Rules are (regex over leaf path) -> PartitionSpec; leaves under a scanned
group get the stack dimension prepended automatically.  This is the single
source of truth consumed by train/serve/dryrun in_shardings.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ParallelConfig",
    "param_specs",
    "opt_state_specs",
    "batch_specs",
    "cache_specs",
    "make_shardings",
    "slice_shardings",
    "path_of",
]


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    fsdp: bool = True
    fsdp_over_pod: bool = False  # ZeRO across pods (DCN) too
    tensor_axis: str = "model"
    dp_axes: tuple = ("pod", "data")
    compress_grads: bool = True  # bf16 gradient collectives
    seq_shard_cache: bool = True  # context-parallel KV when heads don't divide
    anchor_scan_params: bool = True  # constrain scanned per-layer weight
    # slices to their storage layout (stops XLA's involuntary full
    # rematerialization, which miscompiles on some mesh factorizations)


def _present(mesh: Mesh, axes) -> tuple:
    return tuple(a for a in axes if a in mesh.axis_names)


def dp_axes(mesh: Mesh, pc: ParallelConfig) -> tuple:
    return _present(mesh, pc.dp_axes)


def fsdp_axes(mesh: Mesh, pc: ParallelConfig) -> Optional[tuple]:
    if not pc.fsdp:
        return None
    axes = ("pod", "data") if pc.fsdp_over_pod else ("data",)
    out = _present(mesh, axes)
    return out or None


def path_of(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _rules(F, M, FM):
    """F = fsdp axes (or None), M = tensor axis, FM = (F..., M) joint.

    Column-parallel weights shard their OUTPUT dim jointly over (fsdp,
    tensor): the contraction (input) dim stays local, so using the weight
    costs one small all-gather over fsdp (ZeRO-3 fetch) instead of an
    all-reduce of the much larger activation partial sums.  §Perf iteration
    2 (EXPERIMENTS.md) measured ~6x collective-term reduction vs sharding
    the contraction dim.  Order matters.
    """
    return [
        # MoE — experts over the tensor axis (EP); per-expert dims: the
        # contraction dim of each expert einsum must stay local, FSDP
        # shards the other one.
        (r"moe/router$", P(None, None)),
        (r"moe/w[13]$", P(M, None, F)),
        # w2's output (d) dim stays UNSHARDED: FSDP on it conflicts with
        # the group-local combine gather layout (costs an extra (T,k,d)
        # all-reduce over model — §Perf deepseek iteration 4)
        (r"moe/w2$", P(M, F, None)),
        (r"moe/sw[13]$", P(None, FM)),
        (r"moe/sw2$", P(M, F)),
        # MLA
        (r"mixer/wdq$", P(None, F)),
        (r"mixer/wuq$", P(None, FM)),
        (r"mixer/wdkv$", P(None, F)),
        (r"mixer/wukv$", P(None, FM)),
        (r"mixer/(qln|kvln)$", P()),
        # attention (gqa + cross) — column-parallel qkv, row-parallel out
        (r"(mixer|cross)/w[qkv]$", P(None, FM)),
        (r"(mixer|cross)/wo$", P(M, F)),
        (r"(mixer|cross)/(qn|kn)$", P()),
        # mamba
        (r"mixer/in_(z|x|b|c|dt)$", P(None, FM)),
        (r"mixer/conv_w$", P(None, M)),
        (r"mixer/(conv_b|A_log|D|dt_bias|norm)$", P(M)),
        (r"mixer/out_proj$", P(M, F)),
        # FFN — SABLE tiles shard blocks over the tensor axis
        (r"ffn/w[123]$", "ffn"),  # resolved by ndim below
        # embeddings
        (r"(^|/)embed$", P(M, F)),
        (r"lm_head$", P(None, FM)),
        (r"frontend_proj$", P(None, F)),
    ]


def _spec_for(path: str, ndim: int, stacked: bool, F, M, FM) -> P:
    base_ndim = ndim - 1 if stacked else ndim
    spec = None
    for pat, s in _rules(F, M, FM):
        if re.search(pat, path):
            if s == "ffn":
                if base_ndim == 3:  # SABLE tiles (nt, tm, tk)
                    spec = P(M, None, None)
                elif re.search(r"ffn/w2$", path):
                    spec = P(M, F)
                else:
                    spec = P(None, FM)
            else:
                spec = s
            break
    if spec is None:
        spec = P()  # norms, scalars: replicated
    parts = list(spec) + [None] * (base_ndim - len(spec))
    if stacked:
        parts = [None] + parts
    return P(*parts[:ndim]) if ndim else P()


def param_specs(cfg, params) -> object:
    """PartitionSpec pytree for a params pytree (arrays or SDS)."""
    del cfg

    def one(kp, leaf):
        path = path_of(kp)
        stacked = path.startswith(("groups/", "enc_groups/"))
        return _spec_for(path, leaf.ndim, stacked, "__F__", "__M__", "__FM__")

    marked = jax.tree_util.tree_map_with_path(one, params)
    return marked


def opt_state_specs(cfg, params, opt_state):
    """Moments share the param specs; the count scalar is replicated."""
    pspecs = param_specs(cfg, params)
    return {
        "mu": pspecs,
        "nu": pspecs,
        "count": P(),
    }


def batch_specs(cfg, batch) -> dict:
    def one(kp, leaf):
        return P("__DP__", *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_specs(cfg, cache, pc: ParallelConfig = ParallelConfig(), model_size: int = 16):
    """KV/SSM cache specs.  Heads shard over the tensor axis when they
    divide it; otherwise the sequence dim is context-parallel sharded
    (XLA SPMD turns the attention contraction over the sharded sequence
    into partial-softmax + reduce — flash-decoding style)."""

    def one(kp, leaf):
        path = path_of(kp)
        nd = leaf.ndim
        if re.search(r"(attn|cross)/(k|v)$", path):
            # (rep, B, S, K, hd)
            if cfg.n_kv_heads % model_size == 0:
                return P(None, "__DP__", None, "__M__", None)
            if pc.seq_shard_cache:
                return P(None, "__DP__", "__M__", None, None)
            return P(None, "__DP__", None, None, None)
        if re.search(r"attn/(ckv|kr)$", path):
            # (rep, B, S, c) — MLA latent: sequence-sharded (context parallel)
            return P(None, "__DP__", "__M__", None)
        if re.search(r"ssm_cache/conv$", path):
            return P(None, "__DP__", None, "__M__")
        if re.search(r"ssm_cache/ssm$", path):
            return P(None, "__DP__", "__M__", None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, cache)


def slice_shardings(mesh: Mesh, pc: ParallelConfig, tree) -> object:
    """NamedShardings for ONE scanned layer slice of a stacked params
    subtree (paths like ``sub0/mixer/wq``, no leading scan dim).

    This is the storage layout of the per-iteration ``dynamic-slice``
    inside ``lax.scan`` — the same rule table as :func:`param_specs` with
    ``stacked=False``, resolved and shape-sanitized like
    :func:`make_shardings`.  Constraining the slice to it gives the SPMD
    partitioner an explicit anchor between the slice and the (differently
    laid out) use sites, preventing the "involuntary full
    rematerialization" path that both round-trips the weights through a
    replicated layout and, on some mesh factorizations (e.g. ``(2, 4, 1)``
    or ``(2, 2, 2)`` over 8 hosts), miscompiles outright.
    """

    def one(kp, leaf):
        return _spec_for(path_of(kp), leaf.ndim, False, "__F__", "__M__", "__FM__")

    specs = jax.tree_util.tree_map_with_path(one, tree)
    return make_shardings(mesh, pc, specs, tree)


def _axes_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, (tuple, list)) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def make_shardings(mesh: Mesh, pc: ParallelConfig, spec_tree, tree=None):
    """Resolve placeholder axes and wrap in NamedSharding.

    If ``tree`` (arrays or ShapeDtypeStructs matching spec_tree) is given,
    specs are sanitized: sharding is dropped on any dim whose size is not
    divisible by the axis product (pjit's explicit in_shardings require
    exact divisibility — e.g. vocab 256206 over 16, or batch 1 over dp).
    """
    F = fsdp_axes(mesh, pc)
    M = pc.tensor_axis if pc.tensor_axis in mesh.axis_names else None
    DP = dp_axes(mesh, pc)

    fm = tuple(F) if F else ()
    fm = fm + ((M,) if M else ())

    def resolve(s):
        parts = []
        for p in s:
            if p == "__F__":
                parts.append(F)
            elif p == "__M__":
                parts.append(M)
            elif p == "__FM__":
                parts.append(fm if fm else None)
            elif p == "__DP__":
                parts.append(DP if DP else None)
            else:
                parts.append(p)
        return parts

    if tree is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, P(*resolve(s))),
            spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    def fix(s, leaf):
        if not isinstance(s, P):
            return s
        parts = resolve(s)
        shape = getattr(leaf, "shape", ())
        parts = parts[: len(shape)]
        for i, entry in enumerate(parts):
            if entry is not None and shape[i] % _axes_size(mesh, entry) != 0:
                parts[i] = None
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(
        fix, spec_tree, tree, is_leaf=lambda x: isinstance(x, P)
    )
