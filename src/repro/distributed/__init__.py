from .partition import (
    ShardPlan,
    VBRShard,
    block_row_nnz,
    load_shard_plan,
    make_shard_plan,
    partition_nnz_balanced,
    save_shard_plan,
    shard_vbr,
)
from .sharding import (
    ParallelConfig,
    batch_specs,
    cache_specs,
    make_shardings,
    param_specs,
    opt_state_specs,
    slice_shardings,
)
