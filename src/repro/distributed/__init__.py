from .sharding import (
    ParallelConfig,
    batch_specs,
    cache_specs,
    make_shardings,
    param_specs,
    opt_state_specs,
)
