"""Structure-aware VBR partitioning for sharded staged execution.

The paper parallelizes staged kernels by splitting block rows across
workers (Section IV-D); Ahrens & Boman's VBR partitioning work makes the
stronger point that the split should be chosen from the sparsity
*structure* ahead of time.  Block sizes are structure, so the load model
is exact at inspection time: this module cuts the block rows of a VBR
pattern into ``num_shards`` shards balanced by stored-nonzero count (not
row count — a shard of many empty rows costs nothing), and compacts each
shard into its own shard-local VBR whose block-size distribution is all a
device ever stages kernels for.

Everything here is structure-only and device-agnostic.  The indirection
arrays of each shard round-trip through the persistent structure cache
(:mod:`repro.core.cache`) exactly like any other pattern; the partition
decision itself is recorded as a ``kind='shards'`` plan so a warm process
skips the partitioning step too.

Strategies:
  'lpt'         greedy longest-processing-time bin packing over block
                rows (best balance; shard rows are scattered)
  'contiguous'  optimal-bottleneck contiguous split (chains-on-chains via
                binary search over the makespan; preserves row locality)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import vbr as vbrlib
from ..core.cache import PlanCache, TuningPlan, default_cache, plan_key
from ..core.staging import StagingOptions

__all__ = [
    "VBRShard",
    "ShardPlan",
    "block_row_nnz",
    "partition_nnz_balanced",
    "shard_vbr",
    "make_shard_plan",
    "save_shard_plan",
    "load_shard_plan",
]


def block_row_nnz(vbr: vbrlib.VBR) -> np.ndarray:
    """Stored nonzeros per block row — the exact inspection-time load model."""
    sizes = np.zeros(vbr.num_block_rows, dtype=np.int64)
    for t in vbr.blocks():
        sizes[t.block_row] += t.size
    return sizes


def _make_units(vbr: vbrlib.VBR, num_shards: int) -> list[tuple]:
    """Work units ``(block_row, r0, r1, nnz)`` with r0/r1 LOCAL row bounds.

    Every block in a block row spans its full height, so nnz is uniform
    per matrix row within a block row; a block row holding more than the
    per-shard mean is split into row spans so no single unit can dominate
    a shard (the 1.5x balance bound must hold even when one dense block
    row outweighs everything else)."""
    sizes = block_row_nnz(vbr)
    total = int(sizes.sum())
    cap = max(-(-total // num_shards), 1)  # ceil(mean)
    units: list[tuple] = []
    for a, sz in enumerate(sizes.tolist()):
        h = int(vbr.rpntr[a + 1] - vbr.rpntr[a])
        if sz > cap and h > 1:
            parts = min(-(-sz // cap), h)
            bounds = np.linspace(0, h, parts + 1).round().astype(np.int64)
            per_row = sz // h  # blocks span the full height => exact
            for i in range(parts):
                r0, r1 = int(bounds[i]), int(bounds[i + 1])
                if r1 > r0:
                    units.append((a, r0, r1, per_row * (r1 - r0)))
        else:
            units.append((a, 0, h, sz))
    return units


def _partition_lpt(units: list[tuple], num_shards: int) -> list[list[tuple]]:
    order = sorted(range(len(units)), key=lambda i: -units[i][3])
    bins: list[list[int]] = [[] for _ in range(num_shards)]
    loads = np.zeros(num_shards, dtype=np.int64)
    for i in order:
        w = int(np.argmin(loads))
        bins[w].append(i)
        loads[w] += units[i][3]
    return [[units[i] for i in sorted(b)] for b in bins]


def _partition_contiguous(units: list[tuple], num_shards: int) -> list[list[tuple]]:
    """Minimize the bottleneck over contiguous unit ranges: binary search
    the makespan, greedily packing units while under it."""
    U = len(units)
    sizes = np.asarray([u[3] for u in units], dtype=np.int64)
    prefix = np.concatenate([[0], np.cumsum(sizes)])
    total = int(prefix[-1])

    def fits(cap: int) -> list[int] | None:
        cuts, start = [0], 0
        for _ in range(num_shards):
            # furthest end with sum(sizes[start:end]) <= cap
            end = int(np.searchsorted(prefix, prefix[start] + cap, side="right")) - 1
            end = max(end, start + 1) if start < U else start
            cuts.append(min(end, U))
            start = cuts[-1]
        return cuts if cuts[-1] >= U else None

    lo, hi = int(sizes.max(initial=0)), max(total, 1)
    best = None
    while lo <= hi:
        mid = (lo + hi) // 2
        c = fits(mid)
        if c is not None:
            best, hi = c, mid - 1
        else:
            lo = mid + 1
    if best is None:  # num_shards >= U: one unit per shard, rest empty
        best = list(range(U + 1)) + [U] * (num_shards - U)
    return [units[best[i] : best[i + 1]] for i in range(num_shards)]


def partition_nnz_balanced(
    vbr: vbrlib.VBR, num_shards: int, strategy: str = "lpt"
) -> list[list[tuple]]:
    """Cut the matrix into ``num_shards`` row-span lists balanced by
    stored nnz.  Each element is a unit ``(block_row, r0, r1, nnz)``
    (local row bounds within the block row); block rows larger than the
    per-shard mean are split across shards."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    units = _make_units(vbr, num_shards)
    if strategy == "lpt":
        return _partition_lpt(units, num_shards)
    if strategy == "contiguous":
        return _partition_contiguous(units, num_shards)
    raise ValueError(f"unknown partition strategy {strategy!r}")


# ---------------------------------------------------------------------- #
# shard-local structures
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class VBRShard:
    """One shard: a compacted VBR over a set of row spans, plus the
    indirection back into the global matrix.

    A span is ``(block_row, r0, r1)`` with r0/r1 local to the block row —
    usually the full height, but oversized block rows are split across
    shards.  ``vbr.val`` holds the shard's slice of the parent values so
    the shard is immediately stageable/benchmarkable; at runtime a fresh
    global ``val`` is resliced via ``val_index``.
    """

    shard_id: int
    num_shards: int
    spans: tuple  # ((block_row, r0, r1), ...) owned by this shard
    vbr: vbrlib.VBR  # shard-local structure (rows renumbered compactly)
    row_index: np.ndarray  # (local_m,) global row of each local row
    val_index: np.ndarray  # (local_nnz,) global val offset of each local val

    @property
    def block_rows(self) -> np.ndarray:
        """Global block rows this shard touches (possibly partially)."""
        return np.unique(np.asarray([s[0] for s in self.spans], dtype=np.int64))

    @property
    def nnz(self) -> int:
        return int(self.vbr.stored_nnz)

    @property
    def local_m(self) -> int:
        return int(self.vbr.shape[0])


def _norm_spans(vbr: vbrlib.VBR, spans) -> list[tuple]:
    out = []
    for s in spans:
        if np.isscalar(s):  # a bare block-row id = its full span
            a = int(s)
            out.append((a, 0, int(vbr.rpntr[a + 1] - vbr.rpntr[a])))
        else:
            a, r0, r1 = (int(x) for x in tuple(s)[:3])
            out.append((a, r0, r1))
    return sorted(out)


def shard_vbr(
    vbr: vbrlib.VBR, spans, shard_id: int = 0, num_shards: int = 1
) -> VBRShard:
    """Compact the selected row spans of ``vbr`` into a shard-local VBR.

    ``spans`` is a sequence of block-row ids and/or ``(block_row, r0, r1)``
    tuples.  Blocks are stored column-major, so the rows ``[r0, r1)`` of a
    height-``h`` block at offset ``off`` live at ``off + c*h + r`` — the
    per-value gather ``val_index`` keeps the global→shard reslice exact.
    """
    spans = _norm_spans(vbr, spans)
    by_row: dict[int, list] = {}
    for t in vbr.blocks():
        by_row.setdefault(t.block_row, []).append(t)

    rpntr = [0]
    row_index: list[np.ndarray] = []
    bindx: list[int] = []
    bpntrb: list[int] = []
    bpntre: list[int] = []
    indx = [0]
    val_chunks: list[np.ndarray] = []
    for a, r0, r1 in spans:
        ra0 = int(vbr.rpntr[a])
        h = int(vbr.rpntr[a + 1]) - ra0
        rcnt = r1 - r0
        row_index.append(np.arange(ra0 + r0, ra0 + r1, dtype=np.int64))
        rpntr.append(rpntr[-1] + rcnt)
        tasks = by_row.get(a)
        if not tasks or rcnt == 0:
            bpntrb.append(-1)
            bpntre.append(-1)
            continue
        bpntrb.append(len(bindx))
        for t in tasks:
            w = t.width
            bindx.append(t.block_col)
            g = (
                t.val_offset
                + np.arange(w, dtype=np.int64)[:, None] * h
                + r0
                + np.arange(rcnt, dtype=np.int64)[None, :]
            ).reshape(-1)
            val_chunks.append(g)
            indx.append(indx[-1] + w * rcnt)
        bpntre.append(len(bindx))
    val_index = (
        np.concatenate(val_chunks) if val_chunks else np.zeros(0, np.int64)
    )
    sub = vbrlib.VBR(
        shape=(rpntr[-1], vbr.shape[1]),
        rpntr=np.asarray(rpntr, dtype=np.int32),
        cpntr=vbr.cpntr.copy(),
        bindx=np.asarray(bindx, dtype=np.int32),
        bpntrb=np.asarray(bpntrb, dtype=np.int32),
        bpntre=np.asarray(bpntre, dtype=np.int32),
        indx=np.asarray(indx, dtype=np.int64),
        val=np.asarray(vbr.val)[val_index],
    )
    return VBRShard(
        shard_id=shard_id,
        num_shards=num_shards,
        spans=tuple(spans),
        vbr=sub,
        row_index=(
            np.concatenate(row_index) if row_index else np.zeros(0, np.int64)
        ),
        val_index=val_index,
    )


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """A full partition of one VBR pattern into per-device shards."""

    structure_hash: str  # parent pattern hash
    shape: tuple
    num_shards: int
    strategy: str
    shards: tuple

    def nnz_per_shard(self) -> np.ndarray:
        return np.asarray([s.nnz for s in self.shards], dtype=np.int64)

    def imbalance(self) -> float:
        """max shard nnz / mean shard nnz (1.0 = perfectly balanced)."""
        nnz = self.nnz_per_shard()
        mean = nnz.sum() / max(self.num_shards, 1)
        return float(nnz.max(initial=0) / mean) if mean > 0 else 1.0

    def shard_hashes(self) -> list[str]:
        return [vbrlib.structure_hash(s.vbr) for s in self.shards]


def make_shard_plan(
    vbr: vbrlib.VBR, num_shards: int, strategy: str = "lpt"
) -> ShardPlan:
    assignment = partition_nnz_balanced(vbr, num_shards, strategy)
    shards = tuple(
        shard_vbr(vbr, units, shard_id=i, num_shards=num_shards)
        for i, units in enumerate(assignment)
    )
    return ShardPlan(
        structure_hash=vbrlib.structure_hash(vbr),
        shape=tuple(vbr.shape),
        num_shards=num_shards,
        strategy=strategy,
        shards=shards,
    )


# ---------------------------------------------------------------------- #
# persistence (structure only — values never touch the cache)
# ---------------------------------------------------------------------- #
def _partition_key(structure_hash: str, num_shards: int, strategy: str) -> str:
    return plan_key("shards", structure_hash, strategy, num_shards=num_shards)


def save_shard_plan(plan: ShardPlan, cache: PlanCache | None = None) -> str:
    """Persist the partition decision + every shard's indirection arrays."""
    cache = cache if cache is not None else default_cache()
    for s in plan.shards:
        cache.store_structure(s.vbr)
    record = TuningPlan(
        kind="shards",
        structure_hash=plan.structure_hash,
        options=StagingOptions(),  # placeholder; partition is backend-free
        device=plan.strategy,  # device slot holds the (device-agnostic) strategy
        num_workers=plan.num_shards,
        meta={
            "shape": [int(d) for d in plan.shape],
            "num_shards": plan.num_shards,
            "strategy": plan.strategy,
            "spans": [[list(sp) for sp in s.spans] for s in plan.shards],
            "shard_hashes": plan.shard_hashes(),
            "nnz_per_shard": [int(n) for n in plan.nnz_per_shard()],
        },
        source="partition",
    )
    return cache.store_plan(
        _partition_key(plan.structure_hash, plan.num_shards, plan.strategy),
        record,
    )


def load_shard_plan(
    vbr: vbrlib.VBR,
    num_shards: int,
    strategy: str = "lpt",
    cache: PlanCache | None = None,
) -> ShardPlan | None:
    """Rebuild a persisted partition for ``vbr``; None on miss/mismatch."""
    cache = cache if cache is not None else default_cache()
    shash = vbrlib.structure_hash(vbr)
    record = cache.load_plan(_partition_key(shash, num_shards, strategy))
    if record is None or record.meta.get("num_shards") != num_shards:
        return None
    shards = tuple(
        shard_vbr(vbr, spans, shard_id=i, num_shards=num_shards)
        for i, spans in enumerate(record.meta["spans"])
    )
    plan = ShardPlan(
        structure_hash=shash,
        shape=tuple(vbr.shape),
        num_shards=num_shards,
        strategy=strategy,
        shards=shards,
    )
    if plan.shard_hashes() != record.meta.get("shard_hashes"):
        return None  # stale/corrupt record
    return plan
