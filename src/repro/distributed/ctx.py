"""Activation-sharding context.

Model code is mesh-agnostic; when a launcher traces it under
``activation_sharding(mesh, pc)``, the placeholder-annotated constraint
calls resolve to real NamedShardings (standard MaxText-style residual/
logits constraints).  Outside the context (unit tests, single device) the
constraints are no-ops.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["activation_sharding", "constrain", "anchor_params", "DP",
           "MODEL", "NONE"]

DP = "__DP__"
MODEL = "__M__"
NONE = None

_TLS = threading.local()

# jax's trace cache is shared across jit instances: a function traced once
# OUTSIDE an activation_sharding context (constraints no-op'd) is NOT
# retraced when jitted again inside one — the constraint-free jaxpr is
# reused, every placeholder resolution is lost, and the SPMD partitioner
# is left with in_shardings only (which miscompiles outright on some mesh
# factorizations, e.g. (2,4,1)/(2,2,2) over 8 host devices).  Both edges
# therefore invalidate: entering clears traces recorded outside (or under
# a different mesh/config), and exiting clears traces that baked the
# context's concrete NamedShardings in, restoring the no-op-outside
# contract.  Net cost: two global jax.clear_caches() per context block —
# every jit in the process retraces/recompiles afterwards, so hold the
# context around a whole launch phase, not per step.  NESTED re-entries
# of an equal (mesh, pc) are free (fingerprint match).  Caveat: the
# fingerprint is process-global while the ctx is thread-local — tracing
# the same function concurrently from threads inside AND outside a
# context can still cross-contaminate; keep tracing single-threaded
# around context changes.
_LAST_TRACE_KEY = [None]


def _ctx_fingerprint(ctx) -> object:
    if ctx is None:
        return None
    mesh, dp, model, pc = ctx
    # Mesh compares by devices+axis_names: nested re-entry of an equal
    # context is a fingerprint match and skips the clear
    return (mesh, dp, model, pc)


def _invalidate_traces(key) -> None:
    if _LAST_TRACE_KEY[0] != key:
        jax.clear_caches()
        _LAST_TRACE_KEY[0] = key


@contextlib.contextmanager
def activation_sharding(mesh, pc, invalidate: bool = True):
    """``invalidate=False`` skips the trace-cache invalidation: safe ONLY
    when the context is entered inside the traced function itself (e.g.
    ``make_train_step(mesh=...)``), where the constraints are part of
    every trace and the cache can never serve a constraint-free jaxpr."""
    from .sharding import dp_axes

    prev = getattr(_TLS, "ctx", None)
    dp = dp_axes(mesh, pc)
    model = pc.tensor_axis if pc.tensor_axis in mesh.axis_names else None
    _TLS.ctx = (mesh, dp if dp else None, model, pc)
    if invalidate:
        _invalidate_traces(_ctx_fingerprint(_TLS.ctx))
    try:
        yield
    finally:
        _TLS.ctx = prev
        if invalidate:
            _invalidate_traces(_ctx_fingerprint(prev))


def constrain(x, *parts):
    """with_sharding_constraint with DP/MODEL placeholders; no-op without
    an active context."""
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    mesh, dp, model = ctx[:3]
    resolved = []
    for p in parts:
        if p == DP:
            resolved.append(dp)
        elif p == MODEL:
            resolved.append(model)
        else:
            resolved.append(p)
    resolved += [None] * (x.ndim - len(resolved))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved[: x.ndim]))
    )


def anchor_params(tree):
    """Pin a scanned per-layer params slice to its storage sharding.

    Inside ``lax.scan`` each layer's weights arrive as a ``dynamic-slice``
    of the fsdp-sharded stack; without an explicit constraint between that
    slice and the TP-layout use sites (``fetch``), XLA's SPMD partitioner
    falls into its "involuntary full rematerialization" path — slow, and
    on some mesh factorizations ((2,4,1), (2,2,2) over 8 host devices)
    numerically WRONG.  Anchoring every slice leaf to the layout it is
    already stored in costs nothing and removes the ambiguity.  No-op
    outside an activation_sharding context or when
    ``ParallelConfig.anchor_scan_params`` is off.
    """
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return tree
    mesh, pc = ctx[0], ctx[3]
    if not pc.anchor_scan_params:
        return tree
    from .sharding import slice_shardings

    return jax.tree.map(
        jax.lax.with_sharding_constraint, tree, slice_shardings(mesh, pc, tree)
    )


def fetch(w, *parts):
    """ZeRO-3 weight fetch: constrain a parameter to its TP-only layout at
    the USE site.  Storage stays fsdp-sharded; XLA materializes the use as
    a small all-gather over the fsdp axes (and reduce-scatters the gradient
    back), instead of all-reducing activation-sized partial sums — §Perf
    iteration 2b.  Dims beyond ``parts`` are unsharded; no-op outside an
    activation_sharding context."""
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return w
    return constrain(w, *parts)
