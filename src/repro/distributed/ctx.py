"""Activation-sharding context.

Model code is mesh-agnostic; when a launcher traces it under
``activation_sharding(mesh, pc)``, the placeholder-annotated constraint
calls resolve to real NamedShardings (standard MaxText-style residual/
logits constraints).  Outside the context (unit tests, single device) the
constraints are no-ops.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["activation_sharding", "constrain", "DP", "MODEL", "NONE"]

DP = "__DP__"
MODEL = "__M__"
NONE = None

_TLS = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh, pc):
    from .sharding import dp_axes

    prev = getattr(_TLS, "ctx", None)
    dp = dp_axes(mesh, pc)
    model = pc.tensor_axis if pc.tensor_axis in mesh.axis_names else None
    _TLS.ctx = (mesh, dp if dp else None, model)
    try:
        yield
    finally:
        _TLS.ctx = prev


def constrain(x, *parts):
    """with_sharding_constraint with DP/MODEL placeholders; no-op without
    an active context."""
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    mesh, dp, model = ctx
    resolved = []
    for p in parts:
        if p == DP:
            resolved.append(dp)
        elif p == MODEL:
            resolved.append(model)
        else:
            resolved.append(p)
    resolved += [None] * (x.ndim - len(resolved))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved[: x.ndim]))
    )


def fetch(w, *parts):
    """ZeRO-3 weight fetch: constrain a parameter to its TP-only layout at
    the USE site.  Storage stays fsdp-sharded; XLA materializes the use as
    a small all-gather over the fsdp axes (and reduce-scatters the gradient
    back), instead of all-reducing activation-sized partial sums — §Perf
    iteration 2b.  Dims beyond ``parts`` are unsharded; no-op outside an
    activation_sharding context."""
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return w
    return constrain(w, *parts)
