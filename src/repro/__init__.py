"""repro: SABLE (staged blocked evaluation over structured sparse matrices)
as a production JAX training/serving framework."""
__version__ = "1.0.0"
