"""Training loop with fault tolerance and straggler monitoring.

TrainLoop wires: resumable data -> pjit'd step -> async checkpoints.
On (simulated or real) preemption, re-instantiating the loop restores the
latest checkpoint AND seeks the data iterator, resuming bit-exact.

StepMonitor is the straggler-mitigation hook: per-step wall times feed an
outlier detector (> k x running median).  On a real pod the flagged-slow
callback triggers the control plane (replace node / re-mesh via
``checkpoint.restore`` onto the surviving devices); here it is exercised
by tests with injected delays.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager

__all__ = ["StepMonitor", "TrainLoop"]


class StepMonitor:
    def __init__(self, window: int = 32, threshold: float = 3.0):
        self.times: list[float] = []
        self.window = window
        self.threshold = threshold
        self.flagged: list[int] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        self.times.append(dt)
        hist = self.times[-self.window :]
        med = float(np.median(hist))
        is_outlier = len(hist) >= 8 and dt > self.threshold * med
        if is_outlier:
            self.flagged.append(step)
        return is_outlier

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


class TrainLoop:
    def __init__(
        self,
        step_fn: Callable,  # (params, opt, batch, step) -> (params, opt, metrics)
        dataset,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        keep_last_k: int = 3,
        on_straggler: Optional[Callable] = None,
    ):
        self.step_fn = step_fn
        self.dataset = dataset
        self.manager = CheckpointManager(ckpt_dir, keep_last_k) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.monitor = StepMonitor()
        self.on_straggler = on_straggler
        self.step = 0

    def maybe_restore(self, params, opt_state):
        """Resume from the latest checkpoint if one exists."""
        if self.manager and self.manager.latest_step() is not None:
            tree = {"params": params, "opt": opt_state}
            tree, step, extra = self.manager.restore(tree)
            self.step = step
            self.dataset.load_state_dict(extra.get("data", {"step": step}))
            return tree["params"], tree["opt"], True
        return params, opt_state, False

    def run(self, params, opt_state, num_steps: int, log_every: int = 10,
            log_fn=print):
        it = iter(self.dataset)
        metrics = {}
        target = self.step + num_steps
        while self.step < target:
            batch = next(it)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch, self.step
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if self.monitor.record(self.step, dt) and self.on_straggler:
                self.on_straggler(self.step, dt, self.monitor)
            self.step += 1
            if log_every and self.step % log_every == 0:
                log_fn(
                    f"step {self.step} loss {float(metrics['loss']):.4f} "
                    f"({dt*1e3:.0f} ms)"
                )
            if self.manager and self.step % self.ckpt_every == 0:
                self.manager.save_async(
                    self.step,
                    {"params": params, "opt": opt_state},
                    extra={"data": self.dataset.state_dict()},
                )
        if self.manager:
            self.manager.save(
                self.step,
                {"params": params, "opt": opt_state},
                extra={"data": self.dataset.state_dict()},
            )
        return params, opt_state, metrics
