"""Train/eval step builders (pjit-ready pure functions).

The loss keeps logits vocab-sharded end-to-end (log-softmax over a sharded
axis lowers to partial reductions + a small all-reduce — never a gathered
(B,S,V) tensor), which matters at vocab 256k.  Gradients are optionally
cast to bf16 before the optimizer ('gradient compression': halves
reduce-scatter/all-reduce bytes; error is absorbed by Adam's normalizer —
toggle via ParallelConfig.compress_grads).
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..distributed.ctx import activation_sharding
from ..distributed.sharding import ParallelConfig
from ..models.config import ModelConfig
from ..models.transformer import forward_train
from ..optim.adamw import AdamWConfig, adamw_update

__all__ = ["cross_entropy", "make_train_step", "make_eval_step"]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token CE, stable, f32 accumulation, vocab-shard friendly."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    gold = jnp.take_along_axis(shifted, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    pc: ParallelConfig = ParallelConfig(),
    schedule: Optional[Callable] = None,
    mesh=None,
) -> Callable:
    """Returns step(params, opt_state, batch, step) -> (params, opt_state,
    metrics).  Pure; jit/pjit it with the sharding trees from
    ``distributed.sharding``.

    With ``mesh=`` the activation-sharding context is entered inside the
    step itself, so every trace carries the resolved constraints (incl.
    the scanned-weight anchors) without the launcher holding an
    ``activation_sharding`` block around tracing."""

    def loss_fn(params, batch):
        logits, aux = forward_train(params, cfg, batch)
        ce = cross_entropy(logits, batch["labels"])
        return ce + aux, {"ce": ce, "aux": aux}

    def step_fn(params, opt_state, batch, step):
        ctx = (
            activation_sharding(mesh, pc, invalidate=False)
            if mesh is not None
            else contextlib.nullcontext()
        )
        with ctx:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            if pc.compress_grads:
                grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
            lr = schedule(step) if schedule is not None else opt_cfg.lr
            params, opt_state, om = adamw_update(
                params, grads, opt_state, opt_cfg, lr
            )
            metrics = {"loss": loss, "lr": jnp.asarray(lr), **parts, **om}
        return params, opt_state, metrics

    return step_fn


def make_eval_step(cfg: ModelConfig) -> Callable:
    def eval_fn(params, batch):
        logits, _ = forward_train(params, cfg, batch)
        return cross_entropy(logits, batch["labels"])

    return eval_fn
