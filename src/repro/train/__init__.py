from .step import cross_entropy, make_train_step, make_eval_step
from .loop import TrainLoop, StepMonitor
