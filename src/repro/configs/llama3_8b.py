"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — GQA 128k vocab  [arXiv:2407.21783; unverified]."""
from ..models.config import LayerSpec, ModelConfig, SableConfig, uniform_groups


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b",
        family="dense",
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128256,
        groups=uniform_groups(32, LayerSpec(mixer="gqa", ffn="dense")),
        ffn_type="swiglu",
        rope_theta=500000.0,
        tie_embeddings=False,
        remat="dots",
    )


def full_sable(density: float = 0.25) -> ModelConfig:
    """llama3-8b with SABLE block-sparse FFN weights (the paper's technique
    inside the LM) — used for the technique-representative hillclimb cell.
    d_ff rounded to 14336 -> tile-aligned 14336 = 112 * 128."""
    import dataclasses

    return dataclasses.replace(
        full(),
        name="llama3-8b-sable",
        sable=SableConfig(block_m=128, block_n=128, density=density),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-reduced",
        family="dense",
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        groups=uniform_groups(2, LayerSpec(mixer="gqa", ffn="dense")),
        ffn_type="swiglu",
        rope_theta=500000.0,
        tie_embeddings=False,
    )


def reduced_sable() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        reduced(),
        name="llama3-8b-reduced-sable",
        d_model=64,
        d_ff=128,
        n_heads=4,
        n_kv_heads=2,
        sable=SableConfig(block_m=16, block_n=16, density=0.5),
    )
