"""Assigned input shapes (seq_len x global_batch) and applicability rules.

  train_4k     seq=4096    batch=256  -> train_step
  prefill_32k  seq=32768   batch=32   -> serve prefill
  decode_32k   seq=32768   batch=128  -> serve decode (1 token, KV @ 32k)
  long_500k    seq=524288  batch=1    -> long-context decode; ONLY for
               sub-quadratic archs (ssm / hybrid) per the assignment —
               skipped (with a note) for pure full-attention models.

Enc-dec models: the source (speech-frame) length is seq_len // 4
(4x frontend downsampling, stubbed); target length is the shape's seq_len.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg, shape: Shape) -> tuple[bool, str]:
    """(applicable, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (skip per assignment)"
        )
    return True, ""


def src_len(cfg, shape: Shape) -> int:
    """Encoder source length for enc-dec models."""
    return max(shape.seq_len // 4, 8)
