"""mamba2-1.3b [ssm]: 48L d_model=2048 (attn-free) d_ff=0 vocab=50280,
ssm_state=128 — SSD (state-space duality)  [arXiv:2405.21060; unverified]."""
from ..models.config import LayerSpec, ModelConfig, SSMConfig, uniform_groups


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        d_model=2048,
        n_heads=1,  # no attention heads; SSD heads come from SSMConfig
        n_kv_heads=1,
        head_dim=64,
        d_ff=0,
        vocab_size=50280,
        groups=uniform_groups(48, LayerSpec(mixer="mamba", ffn="none")),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
        tie_embeddings=True,
        remat="dots",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-reduced",
        family="ssm",
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        head_dim=16,
        d_ff=0,
        vocab_size=512,
        groups=uniform_groups(2, LayerSpec(mixer="mamba", ffn="none")),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=16),
        tie_embeddings=True,
    )
