"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887; hf].

Layout: 9 super-blocks of 8 layers (7 mamba + 1 attention at position 7);
MoE replaces the MLP on every 2nd layer (16 experts, top-2, expert
d_ff=24576), dense MLP d_ff=24576 elsewhere.
"""
from ..models.config import MoEConfig, ModelConfig, SSMConfig, jamba_groups


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        groups=jamba_groups(9, attn_pos=7, moe_stride=2),
        moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=8),
        ffn_type="swiglu",
        rope_theta=10000.0,
        tie_embeddings=False,
        remat="dots",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-reduced",
        family="hybrid",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        groups=jamba_groups(1, attn_pos=7, moe_stride=2),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=128),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=2, chunk=16),
        ffn_type="swiglu",
        rope_theta=10000.0,
        tie_embeddings=False,
    )
