"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (GQA kv=16)
d_ff=8192 vocab=256206 — enc-dec, multimodal  [arXiv:2308.11596; hf].

Encoder-decoder: a 24-layer bidirectional encoder over precomputed speech
frame embeddings (the w2v-BERT frontend is a STUB per the assignment —
``input_specs`` provides (B, S_src, 1024) frames) and a 24-layer causal
decoder with cross-attention.  kv=16 with 16 heads => standard MHA.
"""
from ..models.config import GroupSpec, LayerSpec, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256206,
        groups=(
            GroupSpec(
                repeat=24,
                layers=(LayerSpec(mixer="gqa", ffn="dense", cross_attn=True),),
            ),
        ),
        enc_groups=(
            GroupSpec(repeat=24, layers=(LayerSpec(mixer="gqa", ffn="dense"),)),
        ),
        ffn_type="gelu",
        rope_theta=10000.0,
        tie_embeddings=True,
        frontend_dim=1024,
        remat="dots",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2-reduced",
        family="audio",
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        groups=(
            GroupSpec(
                repeat=2,
                layers=(LayerSpec(mixer="gqa", ffn="dense", cross_attn=True),),
            ),
        ),
        enc_groups=(
            GroupSpec(repeat=2, layers=(LayerSpec(mixer="gqa", ffn="dense"),)),
        ),
        ffn_type="gelu",
        rope_theta=10000.0,
        tie_embeddings=True,
        frontend_dim=64,
    )
