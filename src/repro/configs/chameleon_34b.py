"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion, VQ image tokens  [arXiv:2405.09818; unverified].

Early fusion: image patches are VQ-quantized into discrete codes living in
the shared 65536 vocab, so the backbone consumes one mixed token stream.
The VQ tokenizer is the modality-frontend stub (input_specs provides token
ids).  Chameleon's qk-norm is enabled (training-stability fix from the
paper).
"""
from ..models.config import LayerSpec, ModelConfig, uniform_groups


def full() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        family="vlm",
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=65536,
        groups=uniform_groups(48, LayerSpec(mixer="gqa", ffn="dense")),
        ffn_type="swiglu",
        qk_norm=True,
        rope_theta=10000.0,
        tie_embeddings=False,
        remat="dots",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-reduced",
        family="vlm",
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        groups=uniform_groups(2, LayerSpec(mixer="gqa", ffn="dense")),
        ffn_type="swiglu",
        qk_norm=True,
        rope_theta=10000.0,
        tie_embeddings=False,
    )
