"""deepseek-v2-236b [moe]: 60L d_model=5120 128H (GQA kv=128) d_ff=1536
vocab=102400, MoE 160e top-6 — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].

Notes: the assignment's d_ff=1536 is the per-expert hidden size; layer 0
uses a dense FFN (d_ff=12288) per the published config.  128H refers to the
MLA head count (MLA caches the 512-d compressed latent + 64-d rope key, not
per-head KV).
"""
from ..models.config import (
    GroupSpec,
    LayerSpec,
    MLAConfig,
    MoEConfig,
    ModelConfig,
)


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=12288,  # dense layer-0 FFN
        vocab_size=102400,
        groups=(
            GroupSpec(repeat=1, layers=(LayerSpec(mixer="mla", ffn="dense"),)),
            GroupSpec(repeat=59, layers=(LayerSpec(mixer="mla", ffn="moe"),)),
        ),
        attn_type="mla",
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_dim=128,
            qk_rope_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=160,
            top_k=6,
            d_ff=1536,
            num_shared=2,
            shared_d_ff=1536,
        ),
        ffn_type="swiglu",
        rope_theta=10000.0,
        tie_embeddings=False,
        remat="dots",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-reduced",
        family="moe",
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        groups=(
            GroupSpec(repeat=1, layers=(LayerSpec(mixer="mla", ffn="dense"),)),
            GroupSpec(repeat=2, layers=(LayerSpec(mixer="mla", ffn="moe"),)),
        ),
        attn_type="mla",
        mla=MLAConfig(
            q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
            v_head_dim=16,
        ),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, num_shared=2, shared_d_ff=32),
        ffn_type="swiglu",
        rope_theta=10000.0,
        tie_embeddings=False,
    )
