"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3  [hf:meta-llama/Llama-3.2-1B; unverified]."""
from ..models.config import LayerSpec, ModelConfig, uniform_groups


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=128256,
        groups=uniform_groups(28, LayerSpec(mixer="gqa", ffn="dense")),
        ffn_type="swiglu",
        rope_theta=500000.0,
        tie_embeddings=True,
        remat="dots",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-reduced",
        family="dense",
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        groups=uniform_groups(2, LayerSpec(mixer="gqa", ffn="dense")),
        ffn_type="swiglu",
        rope_theta=500000.0,
        tie_embeddings=True,
    )
