"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1 — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Every layer routes top-1 over 16 experts plus one always-on shared expert
(d_ff=8192 each).  Early-fusion multimodality enters as tokens (the vision
frontend is out of scope per the assignment's stub rule).
"""
from ..models.config import LayerSpec, MoEConfig, ModelConfig, uniform_groups


def full() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        groups=uniform_groups(48, LayerSpec(mixer="gqa", ffn="moe")),
        moe=MoEConfig(num_experts=16, top_k=1, d_ff=8192, num_shared=1, shared_d_ff=8192),
        ffn_type="swiglu",
        rope_theta=500000.0,
        tie_embeddings=False,
        remat="dots",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e-reduced",
        family="moe",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=512,
        groups=uniform_groups(2, LayerSpec(mixer="gqa", ffn="moe")),
        moe=MoEConfig(num_experts=4, top_k=1, d_ff=96, num_shared=1, shared_d_ff=96),
        ffn_type="swiglu",
        rope_theta=500000.0,
        tie_embeddings=False,
    )
