"""Architecture registry: one module per assigned architecture.

``get_config(name, reduced=False)`` returns the exact published config
(full) or a structure-preserving small config (reduced) for CPU smoke
tests.  ``ARCH_IDS`` is the assignment's architecture pool.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "nemotron-4-15b",
    "llama3.2-3b",
    "granite-8b",
    "llama3-8b",
    "mamba2-1.3b",
    "jamba-1.5-large-398b",
    "deepseek-v2-236b",
    "llama4-scout-17b-a16e",
    "chameleon-34b",
    "seamless-m4t-large-v2",
]

_MODULES = {
    "nemotron-4-15b": "nemotron_4_15b",
    "llama3.2-3b": "llama3_2_3b",
    "granite-8b": "granite_8b",
    "llama3-8b": "llama3_8b",
    "mamba2-1.3b": "mamba2_1_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "chameleon-34b": "chameleon_34b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}


def get_config(name: str, reduced: bool = False):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.reduced() if reduced else mod.full()


from .shapes import SHAPES, shape_applicable  # noqa: E402
