"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — GQA, squared-ReLU  [arXiv:2402.16819; unverified]."""
from ..models.config import LayerSpec, ModelConfig, uniform_groups


def full() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b",
        family="dense",
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        groups=uniform_groups(32, LayerSpec(mixer="gqa", ffn="dense")),
        ffn_type="relu2",
        rope_theta=10000.0,
        tie_embeddings=False,
        remat="dots",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b-reduced",
        family="dense",
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        groups=uniform_groups(2, LayerSpec(mixer="gqa", ffn="dense")),
        ffn_type="relu2",
        rope_theta=10000.0,
        tie_embeddings=False,
    )
