"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 — llama-arch, code  [arXiv:2405.04324; hf]."""
from ..models.config import LayerSpec, ModelConfig, uniform_groups


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=49152,
        groups=uniform_groups(36, LayerSpec(mixer="gqa", ffn="dense")),
        ffn_type="swiglu",
        rope_theta=10000.0,
        tie_embeddings=True,
        remat="dots",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-8b-reduced",
        family="dense",
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        groups=uniform_groups(3, LayerSpec(mixer="gqa", ffn="dense")),
        ffn_type="swiglu",
        rope_theta=10000.0,
        tie_embeddings=True,
    )
