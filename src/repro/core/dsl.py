"""SABLE's staged domain-specific language (paper Section IV-A).

The user writes a function over *one* block, using:

  * ``RepRange``   — a staged range with bounds known at staging time,
  * ``ArrayVal``   — a symbolic array handle (values deferred to runtime),
  * ``ConcreteArrayVal`` — an array whose values ARE available at staging
                     time (used for the density-check extension, Listing 3),
  * ``loopgen(rng, body)`` — emits a loop over ``rng`` (or unrolls it when
                     ``rng`` is a plain Python ``range``),
  * ``isDense(v)`` — staging-time density check on concrete values.

Executing the user function *records* a small loop-nest IR.  Index
expressions are kept affine (``LinExpr``) so that Stage-1 can constant-fold
bounds and offsets exactly like the paper's generated C (Listing 2), and so
that the pattern matcher in ``backends.py`` can recognize block mat-muls and
lower them onto the MXU.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import numpy as np

__all__ = [
    "RepRange",
    "ArrayVal",
    "ConcreteArrayVal",
    "loopgen",
    "isDense",
    "stage_op",
    "StagingError",
    "LinExpr",
    "Const",
    "Load",
    "BinOp",
    "Store",
    "Loop",
    "Program",
]


class StagingError(Exception):
    """Raised when the op leaves the stageable fragment."""


# ---------------------------------------------------------------------- #
# Index expressions: affine in the loop variables
# ---------------------------------------------------------------------- #
class LinExpr:
    """Affine integer expression: sum(coeff_i * var_i) + const."""

    __slots__ = ("coeffs", "const")

    def __init__(self, coeffs: Optional[dict] = None, const: int = 0):
        self.coeffs: dict[str, int] = dict(coeffs or {})
        self.const = int(const)

    @staticmethod
    def of(x: Union["LinExpr", int]) -> "LinExpr":
        if isinstance(x, LinExpr):
            return x
        if isinstance(x, (int, np.integer)):
            return LinExpr({}, int(x))
        raise StagingError(f"cannot treat {type(x)} as an index expression")

    def is_const(self) -> bool:
        return not any(self.coeffs.values())

    # -- algebra ------------------------------------------------------- #
    def __add__(self, o):
        o = LinExpr.of(o)
        c = dict(self.coeffs)
        for k, v in o.coeffs.items():
            c[k] = c.get(k, 0) + v
        return LinExpr(c, self.const + o.const)

    __radd__ = __add__

    def __neg__(self):
        return LinExpr({k: -v for k, v in self.coeffs.items()}, -self.const)

    def __sub__(self, o):
        return self + (-LinExpr.of(o))

    def __rsub__(self, o):
        return LinExpr.of(o) + (-self)

    def __mul__(self, o):
        if isinstance(o, LinExpr):
            if o.is_const():
                o = o.const
            elif self.is_const():
                return o * self.const
            else:
                raise StagingError("non-affine index expression (var * var)")
        o = int(o)
        return LinExpr({k: v * o for k, v in self.coeffs.items()}, self.const * o)

    __rmul__ = __mul__

    def __repr__(self):
        terms = [f"{v}*{k}" for k, v in self.coeffs.items() if v] + [str(self.const)]
        return " + ".join(terms)

    def subst(self, env: dict[str, int]) -> "LinExpr":
        out = LinExpr({}, self.const)
        for k, v in self.coeffs.items():
            if k in env:
                out.const += v * env[k]
            else:
                out.coeffs[k] = out.coeffs.get(k, 0) + v
        return out


def var(name: str) -> LinExpr:
    return LinExpr({name: 1}, 0)


# ---------------------------------------------------------------------- #
# Value expressions (deferred arithmetic over array loads)
# ---------------------------------------------------------------------- #
class Value:
    def _bin(self, op, other, swap=False):
        other = as_value(other)
        lhs, rhs = (other, self) if swap else (self, other)
        # staging-time constant folding
        if isinstance(lhs, Const) and isinstance(rhs, Const):
            return Const(_PYOPS[op](lhs.v, rhs.v))
        return BinOp(op, lhs, rhs)

    def __mul__(self, o):
        return self._bin("*", o)

    def __rmul__(self, o):
        return self._bin("*", o, swap=True)

    def __add__(self, o):
        return self._bin("+", o)

    def __radd__(self, o):
        return self._bin("+", o, swap=True)

    def __sub__(self, o):
        return self._bin("-", o)

    def __rsub__(self, o):
        return self._bin("-", o, swap=True)

    def __truediv__(self, o):
        return self._bin("/", o)


_PYOPS = {
    "*": lambda a, b: a * b,
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "/": lambda a, b: a / b,
}


@dataclasses.dataclass
class Const(Value):
    v: float


@dataclasses.dataclass
class LinValue(Value):
    """An affine index expression used as a value (e.g. ``r1.start + i``)."""

    expr: LinExpr


@dataclasses.dataclass
class Load(Value):
    array: "ArrayVal"
    index: LinExpr


@dataclasses.dataclass
class BinOp(Value):
    op: str
    lhs: Value
    rhs: Value


def as_value(x) -> Value:
    if isinstance(x, Value):
        return x
    if isinstance(x, LinExpr):
        return Const(x.const) if x.is_const() else LinValue(x)
    if isinstance(x, (int, float, np.integer, np.floating)):
        return Const(float(x))
    raise StagingError(f"cannot stage value of type {type(x)}")


# ---------------------------------------------------------------------- #
# Statements
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class Store:
    array: "ArrayVal"
    index: LinExpr
    value: Value
    accumulate: bool


@dataclasses.dataclass
class Loop:
    varname: str
    start: int
    stop: int
    body: list


Program = list  # list[Store | Loop]

# recording context --------------------------------------------------- #
_STACK: list[list] = []


def _emit(stmt) -> None:
    if not _STACK:
        raise StagingError("DSL statement outside of stage_op()")
    _STACK[-1].append(stmt)


# ---------------------------------------------------------------------- #
# User-facing handles
# ---------------------------------------------------------------------- #
class RepRange:
    """A staged range: bounds are Python ints fixed at staging time.

    ``loopgen`` over a RepRange produces a *loop* in the generated code;
    iterating a plain ``range`` instead unrolls it (Listing 3's extension).
    """

    def __init__(self, start: int, stop: int):
        self.start = int(start)
        self.stop = int(stop)

    def __len__(self):
        return max(0, self.stop - self.start)

    def __repr__(self):
        return f"RepRange({self.start}, {self.stop})"


class ArrayVal:
    """Symbolic array whose *values* are deferred to runtime (Stage 2)."""

    def __init__(self, name: str):
        self.name = name

    def __getitem__(self, idx) -> Load:
        return Load(self, LinExpr.of(idx))

    def __setitem__(self, idx, value) -> None:
        idx = LinExpr.of(idx)
        value = as_value(value)
        # Recognize `a[i] += v`, which Python desugars to
        # `a[i] = a[i] + v`: the rhs is Add(Load(a, i), v).
        if (
            isinstance(value, BinOp)
            and value.op == "+"
            and isinstance(value.lhs, Load)
            and value.lhs.array is self
            and _lin_eq(value.lhs.index, idx)
        ):
            _emit(Store(self, idx, value.rhs, accumulate=True))
        else:
            _emit(Store(self, idx, value, accumulate=False))

    def __repr__(self):
        return f"ArrayVal({self.name})"


class ConcreteArrayVal(ArrayVal):
    """Array whose values are known at staging time.

    Loads with constant indices partially evaluate to constants, enabling
    the paper's ``isDense`` check (Listing 3/4) to elide work for zeros at
    Stage 0.
    """

    def __init__(self, name: str, data: np.ndarray):
        super().__init__(name)
        self.data = np.asarray(data)

    def __getitem__(self, idx):
        idx = LinExpr.of(idx)
        if idx.is_const():
            return Const(float(self.data[idx.const]))
        return Load(self, idx)


def _lin_eq(a: LinExpr, b: LinExpr) -> bool:
    d = a - b
    return d.is_const() and d.const == 0


def isDense(v) -> bool:
    """Staging-time density check (paper Listing 3).

    Only meaningful on values that are concrete at Stage 0; symbolic values
    are by definition 'dense' (we cannot elide them at staging time).
    """
    if isinstance(v, Const):
        return v.v != 0
    return True


def loopgen(rng: Union[RepRange, range], body: Callable) -> None:
    """Generate a loop over ``rng`` with ``body`` applied to the iteration
    variable.  RepRange -> staged loop; plain range -> full unroll."""
    if isinstance(rng, RepRange):
        name = f"v{len(_STACK)}_{id(rng) & 0xFFFF:x}"
        frame: list = []
        _STACK.append(frame)
        try:
            body(var(name))
        finally:
            _STACK.pop()
        loop = Loop(name, rng.start, rng.stop, frame)
        _emit(loop)
        return loop
    if isinstance(rng, range):
        for i in rng:  # Stage-0 unrolling
            body(LinExpr({}, i))
        return None
    raise StagingError(f"loopgen expects RepRange or range, got {type(rng)}")


def stage_op(fn: Callable, *args) -> Program:
    """Run the user's op function, recording its loop-nest IR (Stage 0)."""
    frame: list = []
    _STACK.append(frame)
    try:
        fn(*args)
    finally:
        _STACK.pop()
    return frame
