"""SABLE staging engine: Stage 0 -> Stage 1 -> Stage 2 (paper Fig. 5).

Stage 0  the block iterator walks the VBR indirection arrays (pure Python,
         everything concrete) and runs the user's DSL op once per block,
         recording a loop-nest IR with constant bounds/offsets.
Stage 1  the IR is lowered to a specialized JAX program.  Backends:

           'unrolled'  one slice+dot per block, paper-faithful codegen
                       (HLO size O(#blocks), like SABLE's generated C),
           'grouped'   blocks grouped by shape class; one gather + batched
                       einsum + scatter-add per class (HLO size O(#classes)),
           'pallas'    tile-uniformized Pallas TPU kernel with
                       scalar-prefetched block tables (HLO size O(1)),
           'gather'    generic vectorized evaluation of ANY DSL op
                       (the extensibility story of Section IV-A),
           'dia_hybrid' dense diagonals DIA-style + staged remainder,
                       SpMV-only (kernels/dia_hybrid.py, Fukaya et al.),
           'auto'      grouped (CPU/XLA) — pallas on TPU,
           'autotune'  measured choice: micro-benchmark the candidates via
                       ``core.autotune`` and persist the winner on disk
                       (``core.cache``) keyed by structure hash + device.

Stage 2  XLA/Mosaic compiles the specialized program.  Executables are
         cached keyed by the *structure hash* — values are runtime inputs,
         so one binary serves every matrix with the same pattern
         (compile-once / run-many, Section III).

The density-threshold hybrid (paper Listings 3/4, Figs 8/11) routes blocks
whose fill is below ``density_threshold`` to an unrolled COO tail instead of
dense loops, given staging-time ``value_hints``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import vbr as vbrlib
from .backends import BlockMatmul, match_block_matmul, run_vectorized
from .dsl import ArrayVal, RepRange, stage_op
from .ops_dsl import ArrayView, spmm_op, spmv_op
from .uniformize import TiledPattern, uniformize

__all__ = [
    "StagingOptions",
    "StagedKernel",
    "stage_spmv",
    "stage_spmm",
    "stage_block_op",
    "partition_block_rows",
    "clear_cache",
    "cache_info",
]


@dataclasses.dataclass(frozen=True)
class StagingOptions:
    backend: str = "auto"  # auto|autotune|unrolled|grouped|bucketed|pallas|gather
    density_threshold: float = 0.0  # blocks below -> COO tail (needs hints)
    tile: tuple = (8, 128)  # pallas (tm, tk)
    spmm_bn: int = 128  # pallas N-tile
    interpret: Optional[bool] = None  # pallas interpret mode (None=auto)
    prepack: bool = False  # caller passes prepacked tiles to __call__
    dtype: object = None  # cast values (None = keep)

    def key(self) -> tuple:
        return (
            self.backend,
            self.density_threshold,
            self.tile,
            self.spmm_bn,
            self.interpret,
            self.prepack,
            str(self.dtype),
        )


def _resolve_backend(backend: str) -> str:
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "grouped"


# ---------------------------------------------------------------------- #
# Stage-0 inspection
# ---------------------------------------------------------------------- #
def _inspect(vbr: vbrlib.VBR, kind: str, n_cols: Optional[int]) -> list[BlockMatmul]:
    """Run the DSL op over every block (the paper's block iterator) and
    pattern-match the recorded IR into BlockMatmul descriptors."""
    val_av = ArrayVal("val")
    x_av = ArrayVal("x")
    y_av = ArrayVal("y")
    descs: list[BlockMatmul] = []
    for t in vbr.blocks():
        rr = RepRange(t.row_start, t.row_end)
        cr = RepRange(t.col_start, t.col_end)
        view = ArrayView(val_av, t.val_offset)
        if kind == "spmv":
            prog = stage_op(spmv_op, rr, cr, view, x_av, y_av)
        else:
            prog = stage_op(spmm_op, rr, cr, RepRange(0, n_cols), view, x_av, y_av)
        d = match_block_matmul(prog)
        if d is None:  # the canonical ops always match
            raise RuntimeError("op did not match the block-matmul pattern")
        descs.append(d)
    return descs


def _split_by_density(
    descs: list[BlockMatmul],
    hints: Optional[np.ndarray],
    threshold: float,
) -> tuple[list[BlockMatmul], list[BlockMatmul]]:
    if threshold <= 0.0 or hints is None:
        return descs, []
    dense, sparse = [], []
    for d in descs:
        blk = hints[d.val_off : d.val_off + d.h * d.w]
        density = np.count_nonzero(blk) / max(blk.size, 1)
        (dense if density >= threshold else sparse).append(d)
    return dense, sparse


def _coo_from_hints(descs: list[BlockMatmul], hints: np.ndarray):
    """Unrolled (Listing 3/4) path: bake the nonzero coordinates of the
    low-density blocks at staging time."""
    rows, cols, vidx = [], [], []
    for d in descs:
        blk = hints[d.val_off : d.val_off + d.h * d.w]
        (nz,) = np.nonzero(blk)
        rows.append(d.row_start + (nz % d.h))
        cols.append(d.col_start + (nz // d.h))
        vidx.append(d.val_off + nz)
    if not rows:
        return None
    return (
        np.concatenate(rows).astype(np.int32),
        np.concatenate(cols).astype(np.int32),
        np.concatenate(vidx).astype(np.int32),
    )


# ---------------------------------------------------------------------- #
# Shape-class grouping (Stage-1 'grouped' backend)
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class _ShapeClass:
    h: int
    w: int
    vidx: np.ndarray  # (nb, h*w) int32 gather map into val (+1; 0=pad zero)
    xrow: np.ndarray  # (nb, w) int32 (+1; 0 = pad zero)
    yrow: np.ndarray  # (nb, h) int32 (invalid rows point past m => dropped)
    padded: bool


def _next_bucket(n: int) -> int:
    """Round up to 1.25x-spaced buckets: 8,10,12,15,18,22,27,33,41,...
    (<=25% padding per dim; ~1.25x classes vs 1.5x spacing but less
    wasted compute — measured the better trade on both backends)."""
    b = 8
    while b < n:
        b += max(b // 4, 2)
    return b


def _group_by_shape(
    descs: list[BlockMatmul], m_rows: int, bucket: bool = False
) -> list[_ShapeClass]:
    """Group blocks into shape classes.  With ``bucket=True`` (the
    'bucketed' backend), block dims are rounded UP to a coarse bucket grid
    and padded with zeros — trading a bounded amount of compute-over-zeros
    (the paper's own thesis) for O(#buckets) kernels instead of O(#shapes)
    on non-uniformly split matrices."""
    groups: dict[tuple, list[BlockMatmul]] = {}
    for d in descs:
        key = (
            (_next_bucket(d.h), _next_bucket(d.w)) if bucket else (d.h, d.w)
        )
        groups.setdefault(key, []).append(d)
    out = []
    for (h, w), ds in sorted(groups.items()):
        nb = len(ds)
        vidx = np.zeros((nb, h * w), dtype=np.int64)
        xrow = np.zeros((nb, w), dtype=np.int64)
        yrow = np.full((nb, h), m_rows, dtype=np.int64)  # OOB => drop
        for i, d in enumerate(ds):
            # col-major block layout: idx = col*d.h + row (+1 sentinel shift)
            rr = np.arange(d.h)
            cc = np.arange(d.w)
            g = (d.val_off + cc[None, :] * d.h + rr[:, None] + 1)  # (dh, dw)
            v2 = vidx[i].reshape(w, h).T  # view as (h, w) row-major
            v2[: d.h, : d.w] = g
            vidx[i] = v2.T.reshape(-1)
            xrow[i, : d.w] = d.col_start + cc + 1
            yrow[i, : d.h] = d.row_start + rr
        out.append(
            _ShapeClass(
                h=h, w=w,
                vidx=vidx.astype(np.int32),
                xrow=xrow.astype(np.int32),
                yrow=yrow.astype(np.int32),
                padded=True,
            )
        )
    return out


# ---------------------------------------------------------------------- #
# Staged kernel object
# ---------------------------------------------------------------------- #
class StagedKernel:
    """A compiled pattern-specialized sparse kernel: ``fn(val, x) -> y``.

    ``val`` is the VBR value array (runtime), ``x`` the dense operand.
    Metadata (inspection time, #classes, padding fraction) is recorded for
    the paper's inspection-time and codegen-variant experiments.
    """

    def __init__(self, kind, vbr, opts: StagingOptions, hints=None, n_cols=None):
        t0 = time.perf_counter()
        self.kind = kind
        self.opts = opts
        self.backend = _resolve_backend(opts.backend)
        self.m, self.k = vbr.shape
        self.n_cols = n_cols
        self.structure_hash = vbrlib.structure_hash(vbr)
        descs = _inspect(vbr, kind, n_cols)
        self.num_blocks = len(descs)
        dense_descs, sparse_descs = _split_by_density(
            descs, hints, opts.density_threshold
        )
        self.coo = _coo_from_hints(sparse_descs, hints) if sparse_descs else None
        self.descs = dense_descs
        self.classes = None
        self.tiled: Optional[TiledPattern] = None
        if self.backend in ("grouped", "bucketed"):
            self.classes = _group_by_shape(
                dense_descs, self.m, bucket=self.backend == "bucketed"
            )
        elif self.backend == "pallas":
            tm, tk = opts.tile
            self.tiled = uniformize(
                dense_descs, self.m, self.k, vbr.rpntr, vbr.cpntr, tm, tk
            )
        elif self.backend == "gather":
            self._gather_vbr = vbr
        self.stage0_time = time.perf_counter() - t0
        self.compile_time = 0.0
        self._fn = jax.jit(self._build())

    # ------------------------------------------------------------------ #
    def _build(self) -> Callable:
        kind, backend = self.kind, self.backend
        m = self.m
        coo = self.coo
        dtype_cast = self.opts.dtype

        def add_coo(y, val, x):
            if coo is None:
                return y
            rows, cols, vidx = (jnp.asarray(a) for a in coo)
            v = val[vidx]
            if kind == "spmv":
                return y.at[rows].add(v * x[cols])
            return y.at[rows].add(v[:, None] * x[cols])

        if backend == "unrolled":
            descs = self.descs

            def fn(val, x):
                if dtype_cast is not None:
                    val, x = val.astype(dtype_cast), x.astype(dtype_cast)
                y = jnp.zeros(self._out_shape(x), dtype=x.dtype)
                for d in descs:  # one slice+dot per block (paper codegen)
                    blk = val[d.val_off : d.val_off + d.h * d.w]
                    a = blk.reshape(d.w, d.h).T
                    xs = x[d.col_start : d.col_end]
                    y = y.at[d.row_start : d.row_end].add(a @ xs)
                return add_coo(y, val, x)

            return fn

        if backend in ("grouped", "bucketed"):
            classes = self.classes

            def fn(val, x):
                if dtype_cast is not None:
                    val, x = val.astype(dtype_cast), x.astype(dtype_cast)
                # sentinel slot 0 = zero (padding reads); OOB rows dropped
                val1 = jnp.concatenate([jnp.zeros((1,), val.dtype), val])
                if kind == "spmv":
                    x1 = jnp.concatenate([jnp.zeros((1,), x.dtype), x])
                else:
                    x1 = jnp.concatenate(
                        [jnp.zeros((1, x.shape[1]), x.dtype), x], axis=0
                    )
                y = jnp.zeros(self._out_shape(x), dtype=x.dtype)
                for c in classes:
                    a = val1[c.vidx].reshape(-1, c.w, c.h)  # col-major blocks
                    if kind == "spmv":
                        part = jnp.einsum("bwh,bw->bh", a, x1[c.xrow])
                    else:
                        part = jnp.einsum("bwh,bwn->bhn", a, x1[c.xrow])
                    y = y.at[c.yrow].add(part, mode="drop")
                return add_coo(y, val, x)

            return fn

        if backend == "pallas":
            from ..kernels import ops as kops

            tiled = self.tiled
            interpret = self.opts.interpret
            prepack = self.opts.prepack
            bn = self.opts.spmm_bn

            def fn(val, x):
                if dtype_cast is not None:
                    val, x = val.astype(dtype_cast), x.astype(dtype_cast)
                if prepack:
                    tiles = val  # caller already packed via self.pack()
                else:
                    v1 = jnp.concatenate(
                        [jnp.zeros((1,), x.dtype), val.astype(x.dtype)]
                    )
                    tiles = v1[jnp.asarray(tiled.val_gather)].reshape(
                        tiled.n_tiles, tiled.tm, tiled.tk
                    )
                if kind == "spmv":
                    x1 = jnp.concatenate([jnp.zeros((1,), x.dtype), x])
                    xp = x1[jnp.asarray(tiled.x_src)]
                    yp = kops.bsr_spmv(
                        tiles,
                        jnp.asarray(tiled.row_ids),
                        jnp.asarray(tiled.col_ids),
                        xp,
                        m_pad=tiled.m_pad,
                        interpret=interpret,
                    )
                else:
                    x1 = jnp.concatenate(
                        [jnp.zeros((1, x.shape[1]), x.dtype), x], axis=0
                    )
                    xp = x1[jnp.asarray(tiled.x_src)]
                    yp = kops.bsr_spmm(
                        tiles,
                        jnp.asarray(tiled.row_ids),
                        jnp.asarray(tiled.col_ids),
                        xp,
                        m_pad=tiled.m_pad,
                        bn=bn,
                        interpret=interpret,
                    )
                y = yp[jnp.asarray(tiled.y_src)]
                coo_y = add_coo(jnp.zeros_like(y), val.reshape(-1), x) if coo else None
                return y if coo_y is None else y + coo_y

            return fn

        if backend == "gather":
            vbr = self._gather_vbr
            n_cols = self.n_cols

            def fn(val, x):
                if dtype_cast is not None:
                    val, x = val.astype(dtype_cast), x.astype(dtype_cast)
                if kind == "spmv":
                    y = jnp.zeros((m,), dtype=x.dtype)
                    env = {"val": val, "x": x, "y": y}
                    val_av, x_av, y_av = (
                        ArrayVal("val"),
                        ArrayVal("x"),
                        ArrayVal("y"),
                    )
                    for t in vbr.blocks():
                        prog = stage_op(
                            spmv_op,
                            RepRange(t.row_start, t.row_end),
                            RepRange(t.col_start, t.col_end),
                            ArrayView(val_av, t.val_offset),
                            x_av,
                            y_av,
                        )
                        env = run_vectorized(prog, env)
                    return env["y"]
                # spmm via flattened row-major x/y (paper's layout)
                y = jnp.zeros((m * n_cols,), dtype=x.dtype)
                env = {"val": val, "x": x.reshape(-1), "y": y}
                val_av, x_av, y_av = ArrayVal("val"), ArrayVal("x"), ArrayVal("y")
                for t in vbr.blocks():
                    prog = stage_op(
                        spmm_op,
                        RepRange(t.row_start, t.row_end),
                        RepRange(t.col_start, t.col_end),
                        RepRange(0, n_cols),
                        ArrayView(val_av, t.val_offset),
                        x_av,
                        y_av,
                    )
                    env = run_vectorized(prog, env)
                return env["y"].reshape(m, n_cols)

            return fn

        raise ValueError(f"unknown backend {backend}")

    def _out_shape(self, x):
        if self.kind == "spmv":
            return (self.m,)
        return (self.m, x.shape[1])

    # ------------------------------------------------------------------ #
    def pack(self, val: jnp.ndarray) -> jnp.ndarray:
        """Prepack the runtime values into tiles (amortized across calls)."""
        assert self.tiled is not None, "pack() is for the pallas backend"
        v1 = jnp.concatenate([jnp.zeros((1,), val.dtype), val])
        return v1[jnp.asarray(self.tiled.val_gather)].reshape(
            self.tiled.n_tiles, self.tiled.tm, self.tiled.tk
        )

    def __call__(self, val, x):
        return self._fn(val, x)

    def compile(self, val_spec, x_spec) -> "StagedKernel":
        """AOT Stage-2 compile; records the 'inspection' (compile) time the
        paper reports in Tables II/IV."""
        t0 = time.perf_counter()
        self._fn = self._fn.lower(val_spec, x_spec).compile()
        self.compile_time = time.perf_counter() - t0
        return self

    @property
    def inspection_time(self) -> float:
        return self.stage0_time + self.compile_time


# ---------------------------------------------------------------------- #
# Public API + executable cache (compile once / run many)
# ---------------------------------------------------------------------- #
_CACHE: dict[tuple, StagedKernel] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def _cached(kind, vbr, opts, hints, n_cols=None) -> StagedKernel:
    key = (kind, vbrlib.structure_hash(vbr), n_cols, opts.key())
    hit = _CACHE.get(key)
    if hit is not None:
        _CACHE_STATS["hits"] += 1
        return hit
    _CACHE_STATS["misses"] += 1
    kern = StagedKernel(kind, vbr, opts, hints=hints, n_cols=n_cols)
    _CACHE[key] = kern
    return kern


def stage_spmv(
    vbr: vbrlib.VBR,
    opts: StagingOptions = StagingOptions(),
    value_hints: Optional[np.ndarray] = None,
    *,
    mesh=None,
    shards: Optional[int] = None,
    shard_axis: str = "shards",
    model_axis: str = "model",
    shard_strategy: str = "lpt",
    overlap_gather: bool = True,
):
    """Stage a pattern-specialized SpMV kernel.

    With ``mesh=`` (a 1-D or 2-D device mesh, see
    ``launch.mesh.make_staging_mesh``) or ``shards=N``, the block rows are
    partitioned into nnz-balanced shards, each shard is staged for its own
    block-size distribution, and execution runs under ``shard_map`` across
    the mesh (``shards=`` alone: a host-loop reference of the same split).
    Returns a :class:`~repro.core.sharded.ShardedStagedKernel` in that
    case.  ``overlap_gather`` (default on) assembles the output with a
    ``ppermute`` ring inside ``shard_map`` so gather traffic overlaps
    shard compute instead of a trailing all-gather.
    """
    if opts.backend == "dia_hybrid":
        if mesh is not None or shards is not None:
            raise ValueError(
                "backend='dia_hybrid' is unsharded (the diagonal gather "
                "spans the full row range); stage unsharded or pick "
                "another backend for the mesh path"
            )
        from ..kernels.dia_hybrid import stage_dia_hybrid

        return stage_dia_hybrid(vbr, opts=opts)
    if mesh is not None or shards is not None:
        from .sharded import ShardedStagedKernel

        return ShardedStagedKernel(
            "spmv", vbr, opts, num_shards=shards, mesh=mesh,
            shard_axis=shard_axis, model_axis=model_axis,
            strategy=shard_strategy, hints=value_hints,
            overlap_gather=overlap_gather,
        )
    if opts.backend == "autotune":
        from .autotune import autotune_stage

        return autotune_stage(vbr, "spmv", value_hints=value_hints, base_opts=opts)
    hints = vbr.val if (opts.density_threshold > 0 and value_hints is None) else value_hints
    return _cached("spmv", vbr, opts, hints)


def stage_spmm(
    vbr: vbrlib.VBR,
    n_cols: int,
    opts: StagingOptions = StagingOptions(),
    value_hints: Optional[np.ndarray] = None,
    *,
    mesh=None,
    shards: Optional[int] = None,
    shard_axis: str = "shards",
    model_axis: str = "model",
    shard_strategy: str = "lpt",
    overlap_gather: bool = True,
):
    """Stage a pattern-specialized SpMM kernel; ``mesh=``/``shards=`` as in
    :func:`stage_spmv`.  On a 2-D (shards x model) mesh the RHS columns
    are partitioned over the model axis (``n_cols`` must divide evenly)."""
    if opts.backend == "dia_hybrid":
        raise ValueError("backend='dia_hybrid' is SpMV-only")
    if mesh is not None or shards is not None:
        from .sharded import ShardedStagedKernel

        return ShardedStagedKernel(
            "spmm", vbr, opts, num_shards=shards, mesh=mesh,
            shard_axis=shard_axis, model_axis=model_axis,
            strategy=shard_strategy, hints=value_hints,
            n_cols=n_cols, overlap_gather=overlap_gather,
        )
    if opts.backend == "autotune":
        from .autotune import autotune_stage

        return autotune_stage(
            vbr, "spmm", n_cols, value_hints=value_hints, base_opts=opts
        )
    hints = vbr.val if (opts.density_threshold > 0 and value_hints is None) else value_hints
    return _cached("spmm", vbr, opts, hints, n_cols=n_cols)


def stage_block_op(vbr: vbrlib.VBR, user_op: Callable, extra_arrays=("x",)):
    """Extensibility hook (Section IV-A): stage an ARBITRARY user DSL op
    over every block with the generic vectorized backend.

    ``user_op(row_idxs, col_idxs, block_view, *arrays, out)`` is staged per
    block; returns ``fn(val, *arrays, out0) -> out``.
    """
    val_av = ArrayVal("val")
    out_av = ArrayVal("out")
    extra_avs = [ArrayVal(n) for n in extra_arrays]
    progs = []
    for t in vbr.blocks():
        prog = stage_op(
            user_op,
            RepRange(t.row_start, t.row_end),
            RepRange(t.col_start, t.col_end),
            ArrayView(val_av, t.val_offset),
            *extra_avs,
            out_av,
        )
        progs.append(prog)

    @jax.jit
    def fn(val, *args):
        *extras, out0 = args
        env = {"val": val, "out": out0}
        env.update({n: a for n, a in zip(extra_arrays, extras)})
        for prog in progs:
            env = run_vectorized(prog, env)
        return env["out"]

    return fn


def partition_block_rows(vbr: vbrlib.VBR, num_workers: int) -> list[list[int]]:
    """Paper Section IV-D load balancing: group block rows into tasks by
    total block size (greedy longest-processing-time bin packing)."""
    sizes = np.zeros(vbr.num_block_rows, dtype=np.int64)
    for t in vbr.blocks():
        sizes[t.block_row] += t.size
    order = np.argsort(-sizes)
    bins: list[list[int]] = [[] for _ in range(num_workers)]
    loads = np.zeros(num_workers, dtype=np.int64)
    for a in order:
        w = int(np.argmin(loads))
        bins[w].append(int(a))
        loads[w] += int(sizes[a])
    return bins


def clear_cache() -> None:
    import sys

    _CACHE.clear()
    _CACHE_STATS.update(hits=0, misses=0)
    # the reblock/dia wrappers keep their own kernel memos keyed the same
    # way — a "fresh process" simulation must drop those too
    for modname, fn in (
        ("repro.core.reblock", "clear_reblock_cache"),
        ("repro.kernels.dia_hybrid", "clear_dia_cache"),
    ):
        mod = sys.modules.get(modname)
        if mod is not None:
            getattr(mod, fn)()


def cache_info() -> dict:
    return dict(_CACHE_STATS, size=len(_CACHE))
