"""Inspection-time autotuner: measure candidate staged kernels, keep the best.

``backend='auto'`` in ``staging.py`` is a one-line heuristic (pallas on TPU,
grouped elsewhere).  Ahrens & Boman show that format/partition choice for
blocked sparse formats is itself an optimization problem; SpComp argues the
compiler should make sparsity-structure-specific decisions.  This module is
that inspector: given a VBR *structure*, it stages every plausible
``StagingOptions`` candidate, micro-benchmarks each on representative
inputs, and records the measured winner as a :class:`~.cache.TuningPlan`.

The search is an inspection-time cost, paid once per structure: plans are
persisted through :mod:`repro.core.cache` keyed by ``structure_hash`` and
device, so a second process (or a restarted server) staging the same
pattern performs **zero** micro-benchmarks — it loads the plan and stages
the winner directly (compile-once / run-many, extended to tune-once /
run-forever).

Candidate space (gated by structure + device):

  * ``grouped``   always — the portable XLA baseline
  * ``bucketed``  always — fewer shape classes on non-uniform splits
  * ``unrolled``  only for small block counts (HLO size is O(#blocks))
  * ``grouped`` + ``density_threshold`` hybrid — when block fill is low
  * ``pallas``    tile-size sweep, TPU only by default (interpret mode on
                  CPU is orders of magnitude off and would never win)
  * ``gather``    opt-in only — the extensibility fallback, never the fastest

plus the best ``partition_block_rows`` worker split (Section IV-D), chosen
analytically from the block-size histogram rather than timed.

``include_reblock=True`` extends the space with structure-derived
candidates (docs/inspection.md): ``dia_hybrid`` when the detector
(``core/inspect.py``) finds a diagonal-dominant pattern, and composite
``reblock[<strategy>]+<backend>`` candidates that re-partition the VBR
first (``core/reblock.py``, Ahrens-Boman DP / MXU-aligned tiles) and
stage a backend over the reblocked layout.  A winning reblocked plan
records its :class:`~.reblock.ReblockSpec` (``plan.reblock``) and the
reblocked structure is cached under its own hash, so warm restarts apply
the recorded partitions directly — no detection, no DP, no benchmarks.
Extended-space plans live under a ``-rb`` key segment so they never
alias plans tuned over the base space.

At production cardinality even one measurement pass per structure is too
slow; ``autotune(mode="predict")`` ranks the candidates with the learned
cost model fit over the plan-cache corpus (``core/cost_model.py``) and
only measures when the model is uncertain — see that module and
docs/tuning.md for the calibration contract.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np
import jax

from . import staging as staginglib
from . import vbr as vbrlib
from .cache import PlanCache, TuningPlan, default_cache, plan_key
from .staging import StagedKernel, StagingOptions

__all__ = [
    "autotune",
    "autotune_stage",
    "candidate_options",
    "measure",
    "tune_num_workers",
    "autotune_stats",
    "reset_autotune_stats",
    "StructureRateTracker",
    "structure_tracker",
    "observe_structure",
    "choose_format",
    "reset_structure_trackers",
]

# inspection-time knobs (overridable per call)
DEFAULT_WARMUP = 1
DEFAULT_ITERS = 3
MAX_UNROLLED_BLOCKS = 128
PALLAS_TILES = ((8, 128), (16, 128), (8, 256))
HYBRID_THRESHOLD = 0.5
WORKER_CANDIDATES = (1, 2, 4, 8, 16)
MIN_PARALLEL_EFFICIENCY = 0.75

_STATS = {
    "cache_hits": 0,
    "cache_misses": 0,
    "plans_tuned": 0,
    "benchmarks": 0,
    "plans_predicted": 0,
    "predict_fallbacks": 0,
}


def autotune_stats() -> dict:
    return dict(_STATS)


def reset_autotune_stats() -> None:
    _STATS.update({k: 0 for k in _STATS})


# ---------------------------------------------------------------------- #
# staged-VBR vs fixed-block arbitration (structure-change rate)
# ---------------------------------------------------------------------- #
# Staging + measured tuning pay an inspection cost that amortizes only if
# the SAME structure recurs; a structure that changes every call (per-batch
# MoE routing) must take the inspection-free fixed-block op family
# (kernels.bsr_ops) instead.  The tracker watches the stream of structure
# hashes one callsite ("family") produces and measures how often
# consecutive calls disagree — static patterns score ~0, per-batch
# topologies score ~1.
FIXED_BLOCK_CHANGE_RATE = 0.5
MIN_FORMAT_OBSERVATIONS = 4
TRACKER_WINDOW = 32


class StructureRateTracker:
    """Sliding-window observer of one callsite's structure-hash stream."""

    def __init__(self, window: int = TRACKER_WINDOW):
        from collections import deque

        self._hashes = deque(maxlen=int(window))

    def observe(self, structure_hash: str) -> None:
        self._hashes.append(structure_hash)

    @property
    def observations(self) -> int:
        return len(self._hashes)

    def change_rate(self) -> float:
        """Fraction of consecutive observation pairs whose hash changed."""
        hs = list(self._hashes)
        if len(hs) < 2:
            return 0.0
        return sum(a != b for a, b in zip(hs, hs[1:])) / (len(hs) - 1)


_STRUCTURE_TRACKERS: dict = {}


def structure_tracker(family: str, window: int = TRACKER_WINDOW):
    t = _STRUCTURE_TRACKERS.get(family)
    if t is None:
        t = _STRUCTURE_TRACKERS[family] = StructureRateTracker(window)
    return t


def observe_structure(family: str, structure_hash: str) -> None:
    structure_tracker(family).observe(structure_hash)


def reset_structure_trackers() -> None:
    _STRUCTURE_TRACKERS.clear()


def choose_format(
    family: str,
    structure_hash: str,
    *,
    threshold: float = FIXED_BLOCK_CHANGE_RATE,
    min_observations: int = MIN_FORMAT_OBSERVATIONS,
) -> str:
    """Observe ``structure_hash`` for ``family`` and arbitrate the format:

      * ``"staged"``       — structure recurs; keep the measured staged-VBR
                             path (plan cache, autotune, compile-once).
      * ``"fixed_block"``  — structure churns faster than ``threshold``;
                             take the inspection-free fixed-block op family
                             WITHOUT touching the plan cache (a plan per
                             throwaway topology would thrash it).

    The first ``min_observations`` calls stay staged: a one-shot pattern
    is indistinguishable from a static one, and the staged path's
    heuristic fallback is cheap until the rate signal is real.
    """
    t = structure_tracker(family)
    t.observe(structure_hash)
    if t.observations < min_observations:
        return "staged"
    return "fixed_block" if t.change_rate() > threshold else "staged"


# ---------------------------------------------------------------------- #
# candidate enumeration
# ---------------------------------------------------------------------- #
def candidate_options(
    vbr: vbrlib.VBR,
    *,
    device: Optional[str] = None,
    include_pallas: Optional[bool] = None,
    include_gather: bool = False,
    max_unrolled_blocks: int = MAX_UNROLLED_BLOCKS,
) -> list[tuple[str, StagingOptions]]:
    """Enumerate (label, StagingOptions) candidates for one structure."""
    device = device or jax.default_backend()
    if include_pallas is None:
        include_pallas = device == "tpu"
    cands: list[tuple[str, StagingOptions]] = [
        ("grouped", StagingOptions(backend="grouped")),
        ("bucketed", StagingOptions(backend="bucketed")),
    ]
    if vbr.num_blocks <= max_unrolled_blocks:
        cands.append(("unrolled", StagingOptions(backend="unrolled")))
    if vbr.density() < 0.95 and vbr.stored_nnz > 0:
        cands.append(
            (
                f"grouped+hybrid{HYBRID_THRESHOLD}",
                StagingOptions(
                    backend="grouped", density_threshold=HYBRID_THRESHOLD
                ),
            )
        )
    if include_pallas:
        for tm, tk in PALLAS_TILES:
            cands.append(
                (f"pallas[{tm}x{tk}]", StagingOptions(backend="pallas", tile=(tm, tk)))
            )
    if include_gather:
        cands.append(("gather", StagingOptions(backend="gather")))
    return cands


# ---------------------------------------------------------------------- #
# worker-split tuning (paper Section IV-D)
# ---------------------------------------------------------------------- #
def tune_num_workers(
    vbr: vbrlib.VBR,
    candidates: tuple = WORKER_CANDIDATES,
    min_efficiency: float = MIN_PARALLEL_EFFICIENCY,
) -> int:
    """Largest worker count whose LPT partition keeps parallel efficiency
    (total work / (workers * makespan)) above ``min_efficiency``.

    Analytic — no timing needed: block sizes are structure, so the load
    model is exact at inspection time.
    """
    sizes = np.zeros(vbr.num_block_rows, dtype=np.int64)
    for t in vbr.blocks():
        sizes[t.block_row] += t.size
    total = int(sizes.sum())
    if total == 0:
        return 1
    best = 1
    for w in sorted(candidates):
        if w > max(int(np.count_nonzero(sizes)), 1):
            break
        bins = staginglib.partition_block_rows(vbr, w)
        makespan = max(int(sizes[list(b)].sum()) if b else 0 for b in bins)
        if makespan == 0:
            break
        if total / (w * makespan) >= min_efficiency:
            best = w
    return best


# ---------------------------------------------------------------------- #
# micro-benchmark
# ---------------------------------------------------------------------- #
def measure(
    fn, *args, warmup: int = DEFAULT_WARMUP, iters: int = DEFAULT_ITERS
) -> float:
    """Median wall time of ``fn(*args)`` with ``block_until_ready``; every
    call counts toward ``autotune_stats()['benchmarks']`` (the warm-cache
    acceptance check keys off that counter)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    _STATS["benchmarks"] += 1
    return float(np.median(ts))


def _bench_inputs(vbr: vbrlib.VBR, kind: str, n_cols: Optional[int]):
    rng = np.random.default_rng(0)
    val = np.asarray(vbr.val, dtype=np.float32)
    if val.size and not np.any(val):
        val = rng.standard_normal(val.shape).astype(np.float32)
    k = vbr.shape[1]
    if kind == "spmv":
        x = rng.standard_normal(k).astype(np.float32)
    else:
        x = rng.standard_normal((k, n_cols)).astype(np.float32)
    return val, x


def _structure_meta(vbr: vbrlib.VBR) -> dict:
    """Structure summary recorded on every plan.  The block-size moments
    feed the cost model (core/cost_model.py) — they are what separates a
    few-large-blocks structure from a many-tiny-blocks one at equal nnz,
    which is exactly where backend winners diverge.  The structure-class
    fields (core/inspect.py) separate banded/diagonal patterns from
    random-block ones — where the ``dia_hybrid``/reblocked candidates
    diverge from the base backends."""
    from . import inspect as inspectlib

    sizes = np.asarray([t.size for t in vbr.blocks()], dtype=np.int64)
    mean = float(sizes.mean()) if sizes.size else 0.0
    info = inspectlib.detect_structure(vbr)
    return {
        "shape": [int(s) for s in vbr.shape],
        "num_blocks": int(vbr.num_blocks),
        "num_block_rows": int(vbr.num_block_rows),
        "num_block_cols": int(vbr.num_block_cols),
        "stored_nnz": int(vbr.stored_nnz),
        "density": float(vbr.density()),
        "block_size_mean": mean,
        "block_size_min": int(sizes.min()) if sizes.size else 0,
        "block_size_max": int(sizes.max()) if sizes.size else 0,
        "block_size_cv": float(sizes.std() / mean) if mean else 0.0,
        "structure_class": info.structure_class,
        "bandwidth": int(info.bandwidth),
        "bandwidth_frac": float(info.bandwidth_frac),
        "diag_occupancy": float(info.diag_occupancy),
    }


# ---------------------------------------------------------------------- #
# the tuner
# ---------------------------------------------------------------------- #
def autotune(
    vbr: vbrlib.VBR,
    kind: str = "spmv",
    n_cols: Optional[int] = None,
    *,
    mode: str = "measure",
    cost_model=None,
    predict_margin: Optional[float] = None,
    predict_max_distance: Optional[float] = None,
    value_hints: Optional[np.ndarray] = None,
    cache: Optional[PlanCache] = None,
    use_cache: bool = True,
    warmup: int = DEFAULT_WARMUP,
    iters: int = DEFAULT_ITERS,
    include_pallas: Optional[bool] = None,
    include_gather: bool = False,
    include_reblock: bool = False,
    max_unrolled_blocks: int = MAX_UNROLLED_BLOCKS,
) -> TuningPlan:
    """Return the best :class:`TuningPlan` for ``(kind, vbr)``.

    Warm path: the plan is loaded from the persistent cache and **no**
    kernel is staged or benchmarked.  Cold path with ``mode="measure"``
    (default): every candidate from :func:`candidate_options` is staged
    and timed; the winner (and every candidate's timing, for later
    inspection) is persisted along with the structure's indirection
    arrays.

    ``mode="predict"`` consults the learned cost model fit over the
    plan-cache corpus (``core/cost_model.py``) first: when the model is
    confident — every candidate known, the feature vector in-corpus, and
    a clear predicted margin between the top two candidates — the plan is
    built from *predicted* timings (``source="predicted"``) with ZERO
    micro-benchmarks.  Otherwise it falls back to measurement (never
    guessing), and the measured plan lands back in the corpus so the
    model improves online.  ``cost_model=`` pins a pre-loaded model
    (batch warmers fit once, predict many).

    ``include_reblock=True`` additionally enumerates the structure-derived
    candidates (see module docstring) and keys the plan with the ``-rb``
    segment.  The detection + reblocking DP run only on this cold path —
    a cache hit (or a churny ``family=`` pattern, which never reaches the
    tuner) pays neither."""
    if kind not in ("spmv", "spmm"):
        raise ValueError(f"unknown kind {kind!r}")
    if kind == "spmm" and n_cols is None:
        raise ValueError("spmm autotune needs n_cols")
    if mode not in ("measure", "predict"):
        raise ValueError(f"unknown autotune mode {mode!r}")
    device = jax.default_backend()
    shash = vbrlib.structure_hash(vbr)
    key = plan_key(kind, shash, device, n_cols, reblock=include_reblock)
    cache = cache if cache is not None else default_cache()

    if use_cache:
        plan = cache.load_plan(key)
        if plan is not None:
            _STATS["cache_hits"] += 1
            return plan
        _STATS["cache_misses"] += 1

    cands = candidate_options(
        vbr,
        device=device,
        include_pallas=include_pallas,
        include_gather=include_gather,
        max_unrolled_blocks=max_unrolled_blocks,
    )
    spec_by_label: dict = {}
    rvbr_by_label: dict = {}
    dia_offsets = None
    extra_meta: dict = {}
    if include_reblock:
        from . import inspect as inspectlib
        from . import reblock as rblib

        info = inspectlib.detect_structure(vbr)
        if kind == "spmv" and info.wants_dia:
            cands.append(("dia_hybrid", StagingOptions(backend="dia_hybrid")))
            dia_offsets = [int(d) for d in info.dense_offsets]
            extra_meta["dia_offsets"] = dia_offsets
        specs = rblib.propose_reblockings(vbr, device=device)
        if specs:
            # the primary (DP-first) proposal's fill: deterministic from
            # structure alone, so predict-time features match training
            extra_meta["reblock_fill_ratio"] = float(specs[0].fill_ratio)
        for spec in specs:
            rvbr, _ = rblib.apply_reblock(vbr, spec)
            for lbl, opts in candidate_options(
                rvbr,
                device=device,
                include_pallas=include_pallas,
                max_unrolled_blocks=max_unrolled_blocks,
            ):
                full = f"reblock[{spec.strategy}]+{lbl}"
                cands.append((full, opts))
                spec_by_label[full] = spec
                rvbr_by_label[full] = rvbr
            if use_cache:
                # key every proposed reblocked structure in the cache at
                # proposal time: whichever candidate any plan (measured
                # now, predicted later) ends up pinning, warm restarts
                # find the structure under spec.structure_hash and
                # re-derive nothing
                cache.store_structure(rvbr)

    if mode == "predict":
        from . import cost_model as cmlib

        model = (
            cost_model
            if cost_model is not None
            else cmlib.load_or_fit(cache, device, kind)
        )
        if model is not None:
            meta = {**_structure_meta(vbr), **extra_meta}
            feats = cmlib.meta_features(kind, meta, n_cols)
            labels = [lbl for lbl, _ in cands]
            ok, _why = model.confident(
                feats,
                labels,
                margin=(
                    cmlib.DEFAULT_MARGIN
                    if predict_margin is None
                    else predict_margin
                ),
                max_distance=(
                    cmlib.DEFAULT_MAX_DISTANCE
                    if predict_max_distance is None
                    else predict_max_distance
                ),
            )
            if ok:
                preds = model.predict(feats, labels)
                best_label = min(preds, key=preds.get)
                best_spec = spec_by_label.get(best_label)
                plan = TuningPlan(
                    kind=kind,
                    structure_hash=shash,
                    options=dict(cands)[best_label],
                    n_cols=n_cols,
                    device=device,
                    timings=preds,  # estimates, NOT measurements
                    num_workers=tune_num_workers(vbr),
                    meta=meta,
                    source="predicted",
                    reblock=None if best_spec is None else best_spec.to_dict(),
                )
                _STATS["plans_predicted"] += 1
                cmlib._STATS["plans_predicted"] += 1
                if use_cache:
                    cache.store_plan(key, plan)
                    cache.store_structure(vbr)
                    if best_label in rvbr_by_label:
                        cache.store_structure(rvbr_by_label[best_label])
                return plan
        _STATS["predict_fallbacks"] += 1
        cmlib._STATS["predict_fallbacks"] += 1

    hints = value_hints if value_hints is not None else vbr.val
    val, x = _bench_inputs(vbr, kind, n_cols)
    timings: dict[str, float] = {}
    best_label, best_opts, best_t = None, None, float("inf")
    for label, opts in cands:
        try:
            spec = spec_by_label.get(label)
            if spec is not None:
                from . import reblock as rblib

                kern = rblib.stage_reblocked(
                    vbr, spec, opts, kind, n_cols=n_cols, value_hints=value_hints
                )
            elif opts.backend == "dia_hybrid":
                from ..kernels.dia_hybrid import stage_dia_hybrid

                kern = stage_dia_hybrid(vbr, offsets=dia_offsets, opts=opts)
            else:
                kern = staginglib._cached(kind, vbr, opts, hints, n_cols=n_cols)
            t = measure(kern, val, x, warmup=warmup, iters=iters)
        except Exception:  # a candidate that fails to stage just drops out
            continue
        timings[label] = t
        if t < best_t:
            best_label, best_opts, best_t = label, opts, t
    if best_opts is None:
        # every candidate failed (shouldn't happen) — fall back to heuristic
        best_opts = StagingOptions(
            backend=staginglib._resolve_backend("auto")
        )
        source = "heuristic"
    else:
        source = "measured"
    _STATS["plans_tuned"] += 1

    best_spec = spec_by_label.get(best_label)
    if best_spec is not None:
        # the feature records the fill the plan actually pays
        extra_meta["reblock_fill_ratio"] = float(best_spec.fill_ratio)
    plan = TuningPlan(
        kind=kind,
        structure_hash=shash,
        options=best_opts,
        n_cols=n_cols,
        device=device,
        timings=timings,
        num_workers=tune_num_workers(vbr),
        meta={**_structure_meta(vbr), **extra_meta},
        source=source,
        reblock=None if best_spec is None else best_spec.to_dict(),
    )
    if use_cache:
        cache.store_plan(key, plan)
        cache.store_structure(vbr)
        if best_label in rvbr_by_label:
            # key the REBLOCKED structure too: a warm restart loads the
            # plan, applies the recorded partitions, and stages against
            # this hash without re-deriving anything
            cache.store_structure(rvbr_by_label[best_label])
    return plan


def autotune_stage(
    vbr: vbrlib.VBR,
    kind: str = "spmv",
    n_cols: Optional[int] = None,
    *,
    value_hints: Optional[np.ndarray] = None,
    cache: Optional[PlanCache] = None,
    base_opts: Optional[StagingOptions] = None,
    **tune_kwargs,
) -> StagedKernel:
    """Autotune (or load the cached plan) and return the staged winner.

    ``base_opts`` carries the caller's non-tuned fields (``dtype``,
    ``interpret``) onto the winning plan; the tuner owns ``backend``,
    ``tile``, ``spmm_bn`` and ``density_threshold``.  ``prepack`` is
    incompatible with autotuning (the packed-tile layout depends on the
    backend the tuner hasn't picked yet) and raises.

    On a cold tune the winning kernel was already staged for benchmarking
    and sits in the in-memory executable cache, so this performs no extra
    compilation — unless ``base_opts`` modifies the winner.
    """
    if base_opts is not None and base_opts.prepack:
        raise ValueError(
            "prepack=True is incompatible with backend='autotune': the tile "
            "layout depends on the tuned backend; stage with the plan's "
            "options and call .pack() instead"
        )
    plan = autotune(
        vbr, kind, n_cols, value_hints=value_hints, cache=cache, **tune_kwargs
    )
    opts = plan.options
    if base_opts is not None:
        opts = dataclasses.replace(
            opts, dtype=base_opts.dtype, interpret=base_opts.interpret
        )
    if plan.reblock is not None:
        from . import reblock as rblib

        spec = rblib.ReblockSpec.from_dict(plan.reblock)
        return rblib.stage_reblocked(
            vbr, spec, opts, kind, n_cols=n_cols, value_hints=value_hints
        )
    if opts.backend == "dia_hybrid":
        from ..kernels.dia_hybrid import stage_dia_hybrid

        return stage_dia_hybrid(
            vbr, offsets=plan.meta.get("dia_offsets"), opts=opts
        )
    hints = value_hints if value_hints is not None else (
        vbr.val if opts.density_threshold > 0 else None
    )
    return staginglib._cached(kind, vbr, opts, hints, n_cols=n_cols)
