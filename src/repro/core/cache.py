"""Persistent structure cache: tuning plans + VBR structure on disk.

SABLE's contract is compile-once / run-many (paper Section III): everything
derived from the sparsity *pattern* — the staged program, the backend
choice, the tile shapes — is reusable by any process that stages a matrix
with the same ``structure_hash`` (vbr.py).  The in-memory executable cache
in ``staging.py`` only lives for one process; this module is the on-disk
half, so a *second* process (or a restarted server) skips the autotune
search and goes straight to staging with the known-best plan.

Layout (under ``$REPRO_CACHE_DIR`` or ``~/.cache/repro-sable``)::

    plans/<key>.json        winning StagingOptions + measured timings
    structures/<hash>.npz   the VBR indirection arrays (never ``val``)
    models/<key>.json       fitted cost models (core/cost_model.py), keyed
                            by (kind, device, model version)

Plan JSON schema (version 1)::

    {
      "version": 1,
      "kind": "spmv" | "spmm" | "linear",
      "structure_hash": "<16-hex>",
      "n_cols": null | int,
      "device": "cpu" | "tpu" | "gpu",     # plans are device-specific
      "options": {<StagingOptions fields>},
      "timings": {"<candidate label>": seconds, ...},
      "num_workers": int,                   # best partition_block_rows split
      "meta": {"shape": [m, k], "num_blocks": int, "stored_nnz": int, ...},
      "source": "measured" | "heuristic" | "predicted" | "inherited",
      "reblock": {<ReblockSpec fields>}      # OPTIONAL — omitted when absent
    }

``reblock`` (core/reblock.py) is present only when the winning candidate
re-partitions the structure first: it pins the reblocked row/column
partitions and the REBLOCKED structure hash, so a warm restart applies
the recorded partitions directly (pure numpy gather build) — no DP, no
cost evaluation, zero benchmarks.  The reblocked structure itself is
stored in ``structures/`` under its own hash like any other.

``source`` provenance: ``measured`` plans carry micro-benchmark timings
and are the cost-model training corpus; ``predicted`` plans carry the
cost model's runtime *estimates* (never trained on — no feedback loop);
``heuristic``/``inherited`` plans carry no timings worth learning from.

Values are NEVER cached — only structure, exactly the paper's split of
staging-time structure vs runtime data.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Optional

import numpy as np

from . import vbr as vbrlib
from .staging import StagingOptions

__all__ = [
    "PlanCache",
    "TuningPlan",
    "default_cache",
    "set_default_cache",
    "options_to_dict",
    "options_from_dict",
    "plan_key",
]

PLAN_VERSION = 1

_STRUCTURE_FIELDS = ("rpntr", "cpntr", "bindx", "bpntrb", "bpntre", "indx")


@dataclasses.dataclass
class TuningPlan:
    """The inspection-time decision record for one (kind, structure) pair.

    ``options`` always carries a *concrete* backend (never 'auto' or
    'autotune') so staging from a plan is deterministic.
    """

    kind: str
    structure_hash: str
    options: StagingOptions
    n_cols: Optional[int] = None
    device: str = "cpu"
    timings: dict = dataclasses.field(default_factory=dict)
    num_workers: int = 1
    meta: dict = dataclasses.field(default_factory=dict)
    source: str = "measured"
    # ReblockSpec dict (core/reblock.py) when the winner re-partitions the
    # structure first; None (and omitted from JSON) otherwise
    reblock: Optional[dict] = None

    @property
    def best_time(self) -> Optional[float]:
        return min(self.timings.values()) if self.timings else None

    def to_dict(self) -> dict:
        d = {
            "version": PLAN_VERSION,
            "kind": self.kind,
            "structure_hash": self.structure_hash,
            "n_cols": self.n_cols,
            "device": self.device,
            "options": options_to_dict(self.options),
            "timings": dict(self.timings),
            "num_workers": self.num_workers,
            "meta": dict(self.meta),
            "source": self.source,
        }
        if self.reblock is not None:
            d["reblock"] = dict(self.reblock)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TuningPlan":
        if d.get("version") != PLAN_VERSION:
            raise ValueError(f"unsupported plan version {d.get('version')}")
        return cls(
            kind=d["kind"],
            structure_hash=d["structure_hash"],
            options=options_from_dict(d["options"]),
            n_cols=d["n_cols"],
            device=d.get("device", "cpu"),
            timings=d.get("timings", {}),
            num_workers=d.get("num_workers", 1),
            meta=d.get("meta", {}),
            source=d.get("source", "measured"),
            reblock=d.get("reblock"),
        )


def options_to_dict(opts: StagingOptions) -> dict:
    return {
        "backend": opts.backend,
        "density_threshold": opts.density_threshold,
        "tile": list(opts.tile),
        "spmm_bn": opts.spmm_bn,
        "interpret": opts.interpret,
        "prepack": opts.prepack,
        "dtype": None if opts.dtype is None else np.dtype(opts.dtype).name,
    }


def options_from_dict(d: dict) -> StagingOptions:
    dtype = d.get("dtype")
    return StagingOptions(
        backend=d["backend"],
        density_threshold=d.get("density_threshold", 0.0),
        tile=tuple(d.get("tile", (8, 128))),
        spmm_bn=d.get("spmm_bn", 128),
        interpret=d.get("interpret"),
        prepack=d.get("prepack", False),
        dtype=None if dtype is None else np.dtype(dtype),
    )


def plan_key(
    kind: str,
    structure_hash: str,
    device: str,
    n_cols=None,
    shard_id=None,
    num_shards=None,
    model_cols=None,
    reblock: bool = False,
) -> str:
    """Filename-safe cache key.  Plans are per-device: the measured-best
    backend on a TPU (pallas) is not the best on CPU (grouped).

    Sharded staging keys per-shard plans by the PARENT structure hash plus
    ``(shard_id, num_shards)`` — ``...-s3of8`` — so a shard's tuned plan is
    found from the parent pattern without re-deriving the sub-structure
    hash.  ``num_shards`` alone (``...-x8``) keys whole-partition records.
    On a 2-D (shards x model) mesh the SpMM RHS is column-partitioned, so
    each shard stages for its LOCAL column count; ``model_cols`` —
    ``...-mc4`` — keys those plans apart from the full-width ones and a
    warm restart of the same mesh factorization re-benchmarks nothing.
    ``reblock=True`` appends ``-rb``: the plan was tuned with the EXTENDED
    candidate space (reblocking proposals + structure-detected backends,
    core/reblock.py / core/inspect.py).  A winner chosen from a larger
    candidate set must never alias — or be shadowed by — a plan tuned
    without those candidates, so the key segment separates the two worlds
    the same way ``device`` does.
    """
    parts = [kind, structure_hash, device]
    if n_cols is not None:
        parts.append(f"n{int(n_cols)}")
    if shard_id is not None:
        parts.append(f"s{int(shard_id)}of{int(num_shards or 0)}")
    elif num_shards is not None:
        parts.append(f"x{int(num_shards)}")
    if model_cols is not None:
        parts.append(f"mc{int(model_cols)}")
    if reblock:
        parts.append("rb")
    return "-".join(parts)


class PlanCache:
    """On-disk plan + structure store.  Safe for concurrent writers: files
    are written to a temp name and atomically renamed into place."""

    def __init__(self, root: Optional[str] = None):
        self.root = str(
            root
            or os.environ.get("REPRO_CACHE_DIR")
            or os.path.join(os.path.expanduser("~"), ".cache", "repro-sable")
        )

    # ------------------------------------------------------------------ #
    def _plan_path(self, key: str) -> str:
        return os.path.join(self.root, "plans", f"{key}.json")

    def _structure_path(self, structure_hash: str) -> str:
        return os.path.join(self.root, "structures", f"{structure_hash}.npz")

    @staticmethod
    def _atomic_write(path: str, data: bytes) -> None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    # ------------------------------------------------------------------ #
    # plans
    # ------------------------------------------------------------------ #
    def load_plan(self, key: str) -> Optional[TuningPlan]:
        path = self._plan_path(key)
        try:
            with open(path, "rb") as f:
                return TuningPlan.from_dict(json.load(f))
        except FileNotFoundError:
            return None
        except (ValueError, KeyError, json.JSONDecodeError):
            # stale/corrupt entry: treat as a miss, let the writer replace it
            return None

    def store_plan(self, key: str, plan: TuningPlan) -> str:
        path = self._plan_path(key)
        self._atomic_write(
            path, json.dumps(plan.to_dict(), indent=1, sort_keys=True).encode()
        )
        return path

    def has_plan(self, key: str) -> bool:
        return os.path.exists(self._plan_path(key))

    def iter_plans(self, device: Optional[str] = None, kind: Optional[str] = None):
        """Yield every parseable cached plan, optionally filtered by
        device and kind — the cost-model training corpus walks this."""
        d = os.path.join(self.root, "plans")
        if not os.path.isdir(d):
            return
        for name in sorted(os.listdir(d)):
            if not name.endswith(".json"):
                continue
            plan = self.load_plan(name[: -len(".json")])
            if plan is None:
                continue
            if device is not None and plan.device != device:
                continue
            if kind is not None and plan.kind != kind:
                continue
            yield plan

    # ------------------------------------------------------------------ #
    # fitted cost models (core/cost_model.py)
    # ------------------------------------------------------------------ #
    def _model_path(self, key: str) -> str:
        return os.path.join(self.root, "models", f"{key}.json")

    def store_model(self, key: str, doc: dict) -> str:
        path = self._model_path(key)
        self._atomic_write(path, json.dumps(doc, sort_keys=True).encode())
        return path

    def load_model(self, key: str) -> Optional[dict]:
        try:
            with open(self._model_path(key), "rb") as f:
                return json.load(f)
        except FileNotFoundError:
            return None
        except (ValueError, json.JSONDecodeError):
            return None  # corrupt entry: treat as a miss, refit replaces it

    # ------------------------------------------------------------------ #
    # structures (indirection arrays only — never val)
    # ------------------------------------------------------------------ #
    def store_structure(self, vbr: vbrlib.VBR) -> str:
        h = vbrlib.structure_hash(vbr)
        path = self._structure_path(h)
        if os.path.exists(path):
            return path
        import io

        buf = io.BytesIO()
        np.savez_compressed(
            buf,
            shape=np.asarray(vbr.shape, dtype=np.int64),
            **{f: getattr(vbr, f) for f in _STRUCTURE_FIELDS},
        )
        self._atomic_write(path, buf.getvalue())
        return path

    def load_structure(
        self, structure_hash: str, val: Optional[np.ndarray] = None
    ) -> Optional[vbrlib.VBR]:
        """Rebuild a VBR skeleton from the cache.  ``val`` (the runtime
        data) is supplied by the caller; defaults to zeros of the right
        length so the structure is immediately stageable."""
        path = self._structure_path(structure_hash)
        if not os.path.exists(path):
            return None
        with np.load(path) as z:
            fields = {f: z[f] for f in _STRUCTURE_FIELDS}
            shape = tuple(int(s) for s in z["shape"])
        nnz = int(fields["indx"][-1]) if len(fields["indx"]) else 0
        if val is None:
            val = np.zeros((nnz,), dtype=np.float32)
        v = vbrlib.VBR(shape=shape, val=np.asarray(val), **fields)
        if vbrlib.structure_hash(v) != structure_hash:
            return None  # corrupt entry
        return v

    # ------------------------------------------------------------------ #
    def clear(self) -> int:
        """Remove every cached plan/structure; returns #files removed."""
        n = 0
        for sub in ("plans", "structures", "models"):
            d = os.path.join(self.root, sub)
            if not os.path.isdir(d):
                continue
            for name in os.listdir(d):
                if name.endswith((".json", ".npz")):
                    os.unlink(os.path.join(d, name))
                    n += 1
        return n

    def stats(self) -> dict:
        out = {"root": self.root, "plans": 0, "structures": 0, "models": 0}
        for sub, ext in (
            ("plans", ".json"),
            ("structures", ".npz"),
            ("models", ".json"),
        ):
            d = os.path.join(self.root, sub)
            if os.path.isdir(d):
                out[sub] = sum(1 for f in os.listdir(d) if f.endswith(ext))
        return out


# ---------------------------------------------------------------------- #
# process-wide default (tests point it at a tmpdir via REPRO_CACHE_DIR
# or set_default_cache)
# ---------------------------------------------------------------------- #
_DEFAULT: Optional[PlanCache] = None
_DEFAULT_EXPLICIT = False


def default_cache() -> PlanCache:
    """The process default.  An explicit ``set_default_cache`` wins over
    the environment; otherwise the root tracks ``$REPRO_CACHE_DIR``
    (including it being unset again)."""
    global _DEFAULT
    if not _DEFAULT_EXPLICIT:
        resolved = os.environ.get("REPRO_CACHE_DIR") or os.path.join(
            os.path.expanduser("~"), ".cache", "repro-sable"
        )
        if _DEFAULT is None or _DEFAULT.root != resolved:
            _DEFAULT = PlanCache()
    return _DEFAULT


def set_default_cache(cache: Optional[PlanCache]) -> None:
    """Pin the process default (wins over ``$REPRO_CACHE_DIR``); pass
    ``None`` to return to environment-driven resolution."""
    global _DEFAULT, _DEFAULT_EXPLICIT
    _DEFAULT = cache
    _DEFAULT_EXPLICIT = cache is not None
