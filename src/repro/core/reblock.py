"""Cost-optimal VBR reblocking (Ahrens & Boman) — the partition is a choice.

Everything downstream of inspection takes the VBR row/column partition as
given; this module makes the partition itself a tuned decision.  Ahrens &
Boman ("On Optimal Partitioning For Sparse Matrices In Variable Block Row
Format", PAPERS.md) model the cost of a blocking with a *linear* cost
function and show the optimal contiguous partition is a dynamic program.
We use their cost in the natural form for this codebase::

    cost(P) = alpha * num_stored_blocks(P) + stored_entries(P)

``stored_entries`` counts every slot of every stored block — explicit
zeros (fill-in) included, because that is exactly what the staged kernels
compute over.  ``alpha`` prices the per-block overhead a stored block
costs the grouped/bucketed/pallas backends (gather rows, block-table
entries, scatter targets) in stored-entry units.  Fewer, fuller blocks
and more, emptier blocks are now on one axis and the DP minimizes it.

Proposals (``propose_reblockings``) come in two strategies:

  * ``dp``       alternate row-then-column contiguous-partition DP.  Exact
                 over its split-point set; for large matrices the
                 *bounded-cost approximation* kicks in — split points are
                 restricted to the as-given partition boundaries and block
                 spans are bounded by ``max_span`` segments, keeping the
                 DP O(points x max_span x ortho_blocks).
  * ``aligned``  Sylos Labini-style 1-bounded blocking: uniform MXU-shaped
                 tiles (the pallas backend's preferred dims), every block
                 bounded by one hardware tile.  Proposed for TPU targets,
                 or anywhere it beats the as-given cost.

A proposal is carried as a :class:`ReblockSpec` — partitions, model cost,
fill ratio, and the *reblocked* structure hash — and is what a
:class:`~.cache.TuningPlan` records (``plan.reblock``) when a reblocked
candidate wins the autotune measurement.  ``apply_reblock`` turns the
original VBR into the reblocked one plus a ``val_gather`` map, so at
runtime the original value array is re-laid-out with one gather (sentinel
slot 0 = fill zero) and the staged kernel for the *reblocked* structure
does the rest (:class:`ReblockedKernel`).

Warm restarts re-derive nothing: the spec in the plan pins the partitions
(no DP), the reblocked structure is keyed in the cache by its own hash,
and ``reblock_stats()['dp_runs']`` staying 0 is the acceptance check.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from . import vbr as vbrlib
from .inspect import coo_slots

__all__ = [
    "ReblockSpec",
    "ReblockedKernel",
    "partition_cost",
    "optimal_partition_1d",
    "propose_reblockings",
    "apply_reblock",
    "stage_reblocked",
    "reblock_stats",
    "reset_reblock_stats",
    "clear_reblock_cache",
    "RB_ALPHA",
    "MAX_DP_POINTS",
    "MAX_SPAN",
    "MIN_GAIN",
    "ALIGNED_TILE",
    "MAX_ALIGNED_FILL",
]

# cost-model / DP knobs (see docs/inspection.md for the derivation)
RB_ALPHA = 16.0        # per-stored-block overhead, in stored-entry units
MAX_DP_POINTS = 2048   # above this many rows/cols: bounded-cost approximation
MAX_SPAN = 12          # max segments a DP block may span (bounds the DP)
MIN_GAIN = 0.98        # dp proposal must beat as-given cost by >=2%
ALIGNED_TILE = (8, 128)  # MXU-shaped 1-bounded blocking target
MAX_ALIGNED_FILL = 8.0   # drop aligned proposals whose fill explodes

_STATS = {"dp_runs": 0, "proposals": 0, "applies": 0}


def reblock_stats() -> dict:
    return dict(_STATS)


def reset_reblock_stats() -> None:
    _STATS.update({k: 0 for k in _STATS})


@dataclasses.dataclass(frozen=True)
class ReblockSpec:
    """One reblocking proposal: partitions + Ahrens-Boman model cost.

    ``structure_hash`` is the hash of the REBLOCKED structure (the key the
    reblocked plan/structure are cached under); ``fill_ratio`` is stored
    entries of the reblocked layout / stored slots of the original — the
    cost-model feature ``reblock_fill``.
    """

    strategy: str          # "dp" | "aligned{tm}x{tk}"
    rpntr: tuple           # reblocked row partition
    cpntr: tuple           # reblocked column partition
    cost: float            # linear model cost of this blocking
    base_cost: float       # linear model cost of the as-given blocking
    fill_ratio: float      # stored entries / pattern nnz after reblocking
    structure_hash: str    # hash of the REBLOCKED structure
    alpha: float = RB_ALPHA

    def to_dict(self) -> dict:
        return {
            "strategy": self.strategy,
            "rpntr": [int(p) for p in self.rpntr],
            "cpntr": [int(p) for p in self.cpntr],
            "cost": float(self.cost),
            "base_cost": float(self.base_cost),
            "fill_ratio": float(self.fill_ratio),
            "structure_hash": self.structure_hash,
            "alpha": float(self.alpha),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ReblockSpec":
        return cls(
            strategy=d["strategy"],
            rpntr=tuple(int(p) for p in d["rpntr"]),
            cpntr=tuple(int(p) for p in d["cpntr"]),
            cost=float(d["cost"]),
            base_cost=float(d["base_cost"]),
            fill_ratio=float(d["fill_ratio"]),
            structure_hash=d["structure_hash"],
            alpha=float(d.get("alpha", RB_ALPHA)),
        )


# ---------------------------------------------------------------------- #
# the linear cost model
# ---------------------------------------------------------------------- #
def partition_cost(
    rows: np.ndarray,
    cols: np.ndarray,
    rpntr: Sequence[int],
    cpntr: Sequence[int],
    alpha: float = RB_ALPHA,
) -> tuple[float, int, int]:
    """Ahrens-Boman linear cost of blocking the pattern ``(rows, cols)``
    with partitions ``(rpntr, cpntr)``.

    Returns ``(cost, num_blocks, stored_entries)`` where
    ``cost = alpha * num_blocks + stored_entries`` and stored entries
    count full block areas (fill-in included).
    """
    rpntr = np.asarray(rpntr, dtype=np.int64)
    cpntr = np.asarray(cpntr, dtype=np.int64)
    if len(rows) == 0:
        return 0.0, 0, 0
    br = np.searchsorted(rpntr, rows, side="right") - 1
    bc = np.searchsorted(cpntr, cols, side="right") - 1
    C = len(cpntr) - 1
    ucell = np.unique(br * C + bc)
    h = rpntr[ucell // C + 1] - rpntr[ucell // C]
    w = cpntr[ucell % C + 1] - cpntr[ucell % C]
    stored = int((h * w).sum())
    nb = int(len(ucell))
    return alpha * nb + stored, nb, stored


# ---------------------------------------------------------------------- #
# the contiguous-partition DP (one axis, the other fixed)
# ---------------------------------------------------------------------- #
def optimal_partition_1d(
    coord: np.ndarray,
    ortho_block: np.ndarray,
    ortho_widths: np.ndarray,
    base_pts: np.ndarray,
    alpha: float = RB_ALPHA,
    max_span: int = MAX_SPAN,
) -> tuple[np.ndarray, float]:
    """Optimal contiguous partition along one axis, the other axis fixed.

    ``coord`` are pattern coordinates along the partitioned axis,
    ``ortho_block`` the pattern's block index along the FIXED axis (with
    ``ortho_widths`` that partition's block widths).  Split points are
    restricted to ``base_pts`` (ascending, containing 0 and the axis
    length) and a block may span at most ``max_span`` consecutive base
    segments — together these are the bounded-cost approximation that
    keeps the DP tractable on large matrices while staying *exact* when
    ``base_pts`` is every scalar index and ``max_span`` covers the axis.

    Returns ``(split_points, cost)`` where cost is the full linear cost of
    the 2-D blocking (this partition x the fixed ortho partition).
    """
    base_pts = np.asarray(base_pts, dtype=np.int64)
    S = len(base_pts) - 1
    C = len(ortho_widths)
    ortho_widths = np.asarray(ortho_widths, dtype=np.int64)
    _STATS["dp_runs"] += 1
    if S <= 0 or len(coord) == 0:
        return base_pts.astype(np.int32), 0.0
    seg = np.searchsorted(base_pts, coord, side="right") - 1
    hit = np.zeros((S, C), dtype=bool)  # which ortho blocks each segment hits
    hit[seg, ortho_block] = True
    best = np.full(S + 1, np.inf)
    best[0] = 0.0
    back = np.zeros(S + 1, dtype=np.int64)
    for j in range(1, S + 1):
        # grow the candidate block upward from split j, accumulating the
        # hit-set incrementally: nb/wsum only change when new ortho blocks
        # join, so each (i, j) transition is O(C) worst case, O(1) typical
        cur = np.zeros(C, dtype=bool)
        nb = 0
        wsum = 0
        lo = max(0, j - max_span)
        for i in range(j - 1, lo - 1, -1):
            new = hit[i] & ~cur
            if new.any():
                nb += int(new.sum())
                wsum += int(ortho_widths[new].sum())
                cur |= new
            h = int(base_pts[j] - base_pts[i])
            c = best[i] + alpha * nb + h * wsum
            if c < best[j]:
                best[j] = c
                back[j] = i
    pts = [S]
    while pts[-1] > 0:
        pts.append(int(back[pts[-1]]))
    return base_pts[np.asarray(pts[::-1])].astype(np.int32), float(best[S])


def _dp_base_points(n: int, given_pntr: np.ndarray, max_points: int) -> np.ndarray:
    """Scalar-resolution split points when the axis is small; the as-given
    partition boundaries (bounded-cost approximation) when it is not."""
    if n + 1 <= max_points:
        return np.arange(n + 1, dtype=np.int64)
    return np.asarray(given_pntr, dtype=np.int64)


# ---------------------------------------------------------------------- #
# building a VBR from a pattern + partitions (shared with dia_hybrid)
# ---------------------------------------------------------------------- #
def build_vbr_from_coo(
    rows: np.ndarray,
    cols: np.ndarray,
    vidx: np.ndarray,
    rpntr: Sequence[int],
    cpntr: Sequence[int],
    shape: tuple,
    val: Optional[np.ndarray] = None,
) -> tuple[vbrlib.VBR, np.ndarray]:
    """Block the pattern ``(rows, cols)`` with ``(rpntr, cpntr)``.

    Returns ``(vbr, val_gather)`` where ``val_gather`` maps every stored
    slot of the new layout to ``1 + original val index`` (0 = fill zero),
    i.e. ``new_val = concat([0], old_val)[val_gather]``.  ``val`` (the
    original value array) fills the returned VBR's values; omitted, the
    VBR carries the gather of a zero array (a pure structure skeleton).
    """
    rpntr = np.asarray(rpntr, dtype=np.int32)
    cpntr = np.asarray(cpntr, dtype=np.int32)
    R, C = len(rpntr) - 1, len(cpntr) - 1
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vidx = np.asarray(vidx, dtype=np.int64)
    br = np.searchsorted(rpntr, rows, side="right") - 1
    bc = np.searchsorted(cpntr, cols, side="right") - 1
    cell = br * C + bc
    ucell, inv = np.unique(cell, return_inverse=True)  # row-major block order
    ubr = ucell // C
    ubc = ucell % C
    h = (rpntr[ubr + 1] - rpntr[ubr]).astype(np.int64)
    w = (cpntr[ubc + 1] - cpntr[ubc]).astype(np.int64)
    indx = np.concatenate([[0], np.cumsum(h * w)]).astype(np.int64)
    row_starts = np.searchsorted(ubr, np.arange(R))
    row_ends = np.searchsorted(ubr, np.arange(R), side="right")
    nonempty = row_ends > row_starts
    bpntrb = np.where(nonempty, row_starts, -1).astype(np.int32)
    bpntre = np.where(nonempty, row_ends, -1).astype(np.int32)
    # per-entry slot: column-major inside the block
    lr = rows - rpntr[br]
    lc = cols - cpntr[bc]
    pos = indx[inv] + lc * h[inv] + lr
    val_gather = np.zeros(int(indx[-1]), dtype=np.int64)
    val_gather[pos] = vidx + 1
    if val is not None:
        val1 = np.concatenate([np.zeros((1,), dtype=val.dtype), val])
        new_val = val1[val_gather]
    else:
        new_val = np.zeros(int(indx[-1]), dtype=np.float32)
    out = vbrlib.VBR(
        shape=tuple(shape),
        rpntr=rpntr,
        cpntr=cpntr,
        bindx=ubc.astype(np.int32),
        bpntrb=bpntrb,
        bpntre=bpntre,
        indx=indx,
        val=new_val,
    )
    return out, val_gather


# ---------------------------------------------------------------------- #
# proposals
# ---------------------------------------------------------------------- #
def _make_spec(
    strategy: str,
    rows,
    cols,
    rpntr,
    cpntr,
    shape,
    base_cost: float,
    alpha: float,
) -> ReblockSpec:
    cost, _nb, stored = partition_cost(rows, cols, rpntr, cpntr, alpha)
    rvbr, _ = build_vbr_from_coo(rows, cols, np.zeros_like(rows), rpntr, cpntr, shape)
    return ReblockSpec(
        strategy=strategy,
        rpntr=tuple(int(p) for p in rpntr),
        cpntr=tuple(int(p) for p in cpntr),
        cost=float(cost),
        base_cost=float(base_cost),
        fill_ratio=float(stored) / max(len(rows), 1),
        structure_hash=vbrlib.structure_hash(rvbr),
    )


def propose_reblockings(
    vbr: vbrlib.VBR,
    *,
    device: Optional[str] = None,
    alpha: float = RB_ALPHA,
    max_span: int = MAX_SPAN,
    max_dp_points: int = MAX_DP_POINTS,
    min_gain: float = MIN_GAIN,
    include_aligned: Optional[bool] = None,
    tile: tuple = ALIGNED_TILE,
) -> list[ReblockSpec]:
    """Enumerate reblocking proposals for one structure (cold path only —
    warm restarts read the spec off the cached plan and never come here).

    The ``dp`` proposal is included only when its model cost beats the
    as-given blocking by at least ``1 - min_gain`` (a DP that re-derives
    the given partition would only duplicate existing candidates).  The
    ``aligned`` proposal targets the pallas backend and is included on
    TPU devices, or anywhere its model cost already beats as-given.

    The pattern here is every STORED slot (``coo_slots``), not just the
    currently-nonzero entries: the reblocked layout is structure and must
    stay value-faithful when stored-zero slots are later written.
    """
    rows, cols, _ = coo_slots(vbr)
    if len(rows) == 0:
        return []
    m, k = vbr.shape
    if include_aligned is None:
        import jax

        include_aligned = (device or jax.default_backend()) == "tpu"
    base_cost, _, _ = partition_cost(rows, cols, vbr.rpntr, vbr.cpntr, alpha)
    out: list[ReblockSpec] = []

    # dp: alternate row-then-column contiguous-partition DP
    bc0 = np.searchsorted(np.asarray(vbr.cpntr, np.int64), cols, "right") - 1
    cw0 = np.diff(np.asarray(vbr.cpntr, np.int64))
    new_rpntr, _ = optimal_partition_1d(
        rows, bc0, cw0,
        _dp_base_points(m, vbr.rpntr, max_dp_points),
        alpha, max_span,
    )
    br1 = np.searchsorted(np.asarray(new_rpntr, np.int64), rows, "right") - 1
    rh1 = np.diff(np.asarray(new_rpntr, np.int64))
    new_cpntr, dp_cost = optimal_partition_1d(
        cols, br1, rh1,
        _dp_base_points(k, vbr.cpntr, max_dp_points),
        alpha, max_span,
    )
    same = (
        len(new_rpntr) == len(vbr.rpntr)
        and len(new_cpntr) == len(vbr.cpntr)
        and np.array_equal(new_rpntr, vbr.rpntr)
        and np.array_equal(new_cpntr, vbr.cpntr)
    )
    if not same and dp_cost < min_gain * base_cost:
        out.append(
            _make_spec("dp", rows, cols, new_rpntr, new_cpntr,
                       vbr.shape, base_cost, alpha)
        )

    # aligned: MXU-shaped 1-bounded blocking (uniform hardware tiles)
    tm, tk = tile
    a_rpntr = np.unique(np.concatenate([np.arange(0, m, tm), [m]]))
    a_cpntr = np.unique(np.concatenate([np.arange(0, k, tk), [k]]))
    a_same = np.array_equal(a_rpntr, vbr.rpntr) and np.array_equal(
        a_cpntr, vbr.cpntr
    )
    if not a_same:
        spec = _make_spec(
            f"aligned{tm}x{tk}", rows, cols, a_rpntr, a_cpntr,
            vbr.shape, base_cost, alpha,
        )
        if spec.fill_ratio <= MAX_ALIGNED_FILL and (
            include_aligned or spec.cost < base_cost
        ):
            out.append(spec)
    _STATS["proposals"] += len(out)
    return out


def apply_reblock(
    vbr: vbrlib.VBR, spec: ReblockSpec
) -> tuple[vbrlib.VBR, np.ndarray]:
    """Re-lay-out ``vbr`` under ``spec``'s partitions.

    Returns ``(reblocked_vbr, val_gather)``; the gather re-derives the
    reblocked value array from the ORIGINAL one at runtime
    (``new_val = concat([0], val)[val_gather]``), so the original ``val``
    stays the only runtime input.  Pure numpy, O(nnz) — this is the warm
    path (no DP, no cost evaluation).
    """
    rows, cols, vidx = coo_slots(vbr)
    rvbr, gather = build_vbr_from_coo(
        rows, cols, vidx, spec.rpntr, spec.cpntr, vbr.shape, val=np.asarray(vbr.val)
    )
    if vbrlib.structure_hash(rvbr) != spec.structure_hash:
        raise ValueError(
            "reblock spec does not match this structure (stale plan?): "
            f"expected {spec.structure_hash}, got {vbrlib.structure_hash(rvbr)}"
        )
    _STATS["applies"] += 1
    return rvbr, gather


# ---------------------------------------------------------------------- #
# the staged wrapper
# ---------------------------------------------------------------------- #
class ReblockedKernel:
    """``fn(val, x) -> y`` over the ORIGINAL value layout: one gather
    re-lays the values out under the reblocked partitions (sentinel slot 0
    supplies the fill zeros), then the staged kernel for the reblocked
    structure runs.  Metadata mirrors :class:`~.staging.StagedKernel`."""

    def __init__(self, inner, val_gather: np.ndarray, spec: ReblockSpec, kind: str):
        import jax
        import jax.numpy as jnp

        self.inner = inner
        self.spec = spec
        self.kind = kind
        self.backend = inner.backend
        self.opts = inner.opts
        self.structure_hash = spec.structure_hash
        gather = jnp.asarray(val_gather)

        def fn(val, x):
            val1 = jnp.concatenate([jnp.zeros((1,), val.dtype), val])
            return inner(val1[gather], x)

        self._fn = jax.jit(fn)

    def __call__(self, val, x):
        return self._fn(val, x)

    @property
    def inspection_time(self) -> float:
        return self.inner.inspection_time


_KERNELS: dict[tuple, ReblockedKernel] = {}


def stage_reblocked(
    vbr: vbrlib.VBR,
    spec: ReblockSpec,
    opts,
    kind: str = "spmv",
    n_cols: Optional[int] = None,
    value_hints=None,
) -> ReblockedKernel:
    """Stage ``kind`` for ``vbr`` under ``spec``'s reblocked layout.

    The inner kernel is staged (and in-memory cached) against the
    REBLOCKED structure hash, so repeated staging of the same (structure,
    spec, options) reuses both the executable and the wrapper.
    """
    from . import staging as staginglib

    key = (
        vbrlib.structure_hash(vbr),
        spec.structure_hash,
        kind,
        n_cols,
        opts.key(),
    )
    hit = _KERNELS.get(key)
    if hit is not None:
        return hit
    rvbr, gather = apply_reblock(vbr, spec)
    hints = None
    if opts.density_threshold > 0:
        # hints index the REBLOCKED layout: re-lay the caller's hints (or
        # the original values) out with the same gather the runtime uses
        src = np.asarray(value_hints if value_hints is not None else vbr.val)
        hints = np.concatenate([np.zeros((1,), src.dtype), src])[gather]
    inner = staginglib._cached(kind, rvbr, opts, hints, n_cols=n_cols)
    kern = ReblockedKernel(inner, gather, spec, kind)
    _KERNELS[key] = kern
    return kern


def clear_reblock_cache() -> None:
    _KERNELS.clear()
