"""Stage-1 backends for the staged loop-nest IR.

Three evaluation strategies over a recorded ``Program``:

  * ``run_reference``      — elementwise NumPy interpretation (oracle),
  * ``run_vectorized``     — generic gather/scatter-add JAX evaluation of
                             any op in the DSL fragment,
  * ``match_block_matmul`` — recognizes the canonical dense-block
                             contraction (SpMV / SpMM bodies) and returns a
                             descriptor that ``staging.py`` lowers to
                             slice + dot (XLA) or to the Pallas kernels.

The matcher is the Stage-1 'constant folding' of the paper (Listing 2): it
proves that the loop nest is a dense column-major block times a dense
vector/matrix and extracts the constant bounds and value-array offset.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

try:  # jax is optional for the pure-NumPy oracle
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jnp = None

from .dsl import (
    BinOp,
    Const,
    LinExpr,
    LinValue,
    Load,
    Loop,
    Program,
    StagingError,
    Store,
    Value,
)

__all__ = [
    "run_reference",
    "run_vectorized",
    "match_block_matmul",
    "BlockMatmul",
]


# ---------------------------------------------------------------------- #
# Reference interpreter (oracle)
# ---------------------------------------------------------------------- #
def _eval_value_scalar(v: Value, ivars: dict, env: dict):
    if isinstance(v, Const):
        return v.v
    if isinstance(v, LinValue):
        e = v.expr.subst(ivars)
        if not e.is_const():
            raise StagingError("unbound loop var in value")
        return e.const
    if isinstance(v, Load):
        idx = v.index.subst(ivars)
        if not idx.is_const():
            raise StagingError("unbound loop var in load index")
        return env[v.array.name][idx.const]
    if isinstance(v, BinOp):
        a = _eval_value_scalar(v.lhs, ivars, env)
        b = _eval_value_scalar(v.rhs, ivars, env)
        return {"*": a * b, "+": a + b, "-": a - b, "/": a / b if v.op == "/" else None}[
            v.op
        ] if v.op in "*+-/" else None
    raise StagingError(f"cannot interpret {v}")


def _run_stmt_ref(stmt, ivars: dict, env: dict) -> None:
    if isinstance(stmt, Loop):
        for i in range(stmt.start, stmt.stop):
            ivars[stmt.varname] = i
            for s in stmt.body:
                _run_stmt_ref(s, ivars, env)
        ivars.pop(stmt.varname, None)
    elif isinstance(stmt, Store):
        idx = stmt.index.subst(ivars)
        if not idx.is_const():
            raise StagingError("unbound loop var in store index")
        val = _eval_value_scalar(stmt.value, ivars, env)
        if stmt.accumulate:
            env[stmt.array.name][idx.const] += val
        else:
            env[stmt.array.name][idx.const] = val
    else:
        raise StagingError(f"unknown stmt {stmt}")


def run_reference(program: Program, env: dict) -> None:
    """Interpret the program elementwise over NumPy arrays (in place)."""
    for stmt in program:
        _run_stmt_ref(stmt, {}, env)


# ---------------------------------------------------------------------- #
# Generic vectorized JAX evaluation (gather / scatter-add)
# ---------------------------------------------------------------------- #
def _loop_nest(stmt, loops):
    """Yield (loops, store) leaves of the nest."""
    if isinstance(stmt, Loop):
        for s in stmt.body:
            yield from _loop_nest(s, loops + [stmt])
    elif isinstance(stmt, Store):
        yield loops, stmt


def _eval_lin_grid(e: LinExpr, grids: dict):
    out = e.const
    for k, c in e.coeffs.items():
        if c:
            out = out + c * grids[k]
    return out


def _eval_value_grid(v: Value, grids: dict, env: dict):
    if isinstance(v, Const):
        return v.v
    if isinstance(v, LinValue):
        return _eval_lin_grid(v.expr, grids)
    if isinstance(v, Load):
        idx = _eval_lin_grid(v.index, grids)
        arr = env[v.array.name]
        return arr[idx]
    if isinstance(v, BinOp):
        a = _eval_value_grid(v.lhs, grids, env)
        b = _eval_value_grid(v.rhs, grids, env)
        if v.op == "*":
            return a * b
        if v.op == "+":
            return a + b
        if v.op == "-":
            return a - b
        if v.op == "/":
            return a / b
    raise StagingError(f"cannot vectorize {v}")


def run_vectorized(program: Program, env: dict) -> dict:
    """Evaluate the program with one broadcasted index grid per loop nest.

    Returns the updated environment (functional: arrays are jnp).  Loads
    become gathers, accumulating stores become ``.at[].add`` scatter-adds
    (duplicate indices sum, matching sequential semantics for '+=').
    """
    assert jnp is not None, "jax required for the vectorized backend"
    env = dict(env)
    for top in program:
        for loops, store in _loop_nest(top, []):
            grids = {}
            for ax, lp in enumerate(loops):
                shape = [1] * len(loops)
                shape[ax] = lp.stop - lp.start
                grids[lp.varname] = jnp.arange(lp.start, lp.stop).reshape(shape)
            val = _eval_value_grid(store.value, grids, env)
            idx = _eval_lin_grid(store.index, grids)
            target = env[store.array.name]
            if isinstance(idx, (int, np.integer)):
                idx = jnp.asarray(idx)
            shape = np.broadcast_shapes(
                getattr(val, "shape", ()), getattr(idx, "shape", ())
            )
            val = jnp.broadcast_to(val, shape).reshape(-1)
            idx = jnp.broadcast_to(idx, shape).reshape(-1)
            if store.accumulate:
                env[store.array.name] = target.at[idx].add(
                    val.astype(target.dtype))
            else:
                env[store.array.name] = target.at[idx].set(
                    val.astype(target.dtype))
    return env


# ---------------------------------------------------------------------- #
# Pattern matcher: dense-block contraction
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class BlockMatmul:
    """Stage-1 descriptor of ``y[rows] += A_block @ x[cols]``.

    A_block is the column-major dense block ``val[val_off : val_off+h*w]``
    of shape (h, w) reshaped from (w, h) storage.  For SpMM, ``n_cols`` is
    the dense right-hand matrix width (paper's col_width, e.g. 512) and x/y
    are row-major (rows x n_cols); for SpMV ``n_cols`` is None.
    """

    row_start: int
    row_end: int
    col_start: int
    col_end: int
    val_off: int
    n_cols: Optional[int]  # None => SpMV
    y_name: str = "y"
    x_name: str = "x"
    a_name: str = "val"

    @property
    def h(self) -> int:
        return self.row_end - self.row_start

    @property
    def w(self) -> int:
        return self.col_end - self.col_start


def _single_store(program: Program):
    """The canonical ops are one perfect nest with a single accumulate."""
    leaves = []
    for top in program:
        leaves.extend(_loop_nest(top, []))
    if len(leaves) != 1:
        return None
    loops, store = leaves[0]
    if not store.accumulate:
        return None
    return loops, store


def _as_mul_of_loads(v: Value):
    if isinstance(v, BinOp) and v.op == "*":
        if isinstance(v.lhs, Load) and isinstance(v.rhs, Load):
            return v.lhs, v.rhs
    return None


def match_block_matmul(program: Program) -> Optional[BlockMatmul]:
    """Recognize the SpMV / SpMM bodies of Section IV-B/C and extract the
    constant bounds/offsets (the paper's Listing 2 specialization)."""
    leaf = _single_store(program)
    if leaf is None:
        return None
    loops, store = leaf
    if len(loops) not in (2, 3):
        return None
    pair = _as_mul_of_loads(store.value)
    if pair is None:
        return None
    bounds = {lp.varname: (lp.start, lp.stop) for lp in loops}

    # try both operand orders: one load is the block (A), the other is x
    for a_load, x_load in (pair, pair[::-1]):
        m = _try_match(loops, bounds, store, a_load, x_load)
        if m is not None:
            return m
    return None


def _coeffs(e: LinExpr, names):
    return {n: e.coeffs.get(n, 0) for n in names}


def _try_match(loops, bounds, store, a_load, x_load) -> Optional[BlockMatmul]:
    names = [lp.varname for lp in loops]
    a_c = _coeffs(a_load.index, names)
    x_c = _coeffs(x_load.index, names)
    y_c = _coeffs(store.index, names)

    if len(loops) == 2:
        # SpMV: find i (row var: appears in y and A with coeff 1) and
        # j (col var: appears in x with coeff 1 and A with coeff h).
        for i, j in itertools.permutations(names, 2):
            h = bounds[i][1] - bounds[i][0]
            if (
                y_c[i] == 1 and y_c[j] == 0
                and x_c[j] == 1 and x_c[i] == 0
                and a_c[i] == 1 and a_c[j] == h
            ):
                i0, i1 = bounds[i]
                j0, j1 = bounds[j]
                # A index = (j-j0)*h + (i-i0) + off  (column-major block)
                off = a_load.index.const + j0 * h + i0
                row0 = i0 + store.index.const
                col0 = j0 + x_load.index.const
                return BlockMatmul(
                    row_start=row0,
                    row_end=row0 + (i1 - i0),
                    col_start=col0,
                    col_end=col0 + (j1 - j0),
                    val_off=off,
                    n_cols=None,
                    y_name=store.array.name,
                    x_name=x_load.array.name,
                    a_name=a_load.array.name,
                )
        return None

    # SpMM: vars i (rows of y), k (cols of block / rows of x), j (dense cols)
    for i, k, j in itertools.permutations(names, 3):
        h = bounds[i][1] - bounds[i][0]
        j0, j1 = bounds[j]
        cw = j1 - j0  # dense column width must span the full row (j0 == 0)
        if j0 != 0 or cw <= 0:
            continue
        if (
            y_c[j] == 1 and y_c[i] == cw and y_c[k] == 0
            and x_c[j] == 1 and x_c[k] == cw and x_c[i] == 0
            and a_c[i] == 1 and a_c[k] == h and a_c[j] == 0
        ):
            i0, i1 = bounds[i]
            k0, k1 = bounds[k]
            off = a_load.index.const + k0 * h + i0
            # constant parts of y/x indices encode row offsets * cw
            if store.index.const % cw or x_load.index.const % cw:
                continue
            row0 = i0 + store.index.const // cw
            col0 = k0 + x_load.index.const // cw
            return BlockMatmul(
                row_start=row0,
                row_end=row0 + (i1 - i0),
                col_start=col0,
                col_end=col0 + (k1 - k0),
                val_off=off,
                n_cols=cw,
                y_name=store.array.name,
                x_name=x_load.array.name,
                a_name=a_load.array.name,
            )
    return None
