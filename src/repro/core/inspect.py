"""Structure-class detection: what *kind* of sparsity is this?

The autotuner (``core/autotune.py``) measures backend candidates over the
VBR blocking it is handed — but whole families of structures deserve
candidates the generic enumeration would never propose.  Fukaya et al.
(PAPERS.md, "Accelerating the SpMV kernel ... partially diagonal
structures") show banded / partially-diagonal matrices want their dense
diagonals stored as DIA vectors (contiguous, scatter-free) with only the
remainder going through the general path; Ahrens & Boman show the
blocking itself should be re-derived when it fits the pattern badly.

This module is the classifier both of those decisions key off.  It works
on the scalar *pattern* (never the values — an all-zero ``val``, e.g. a
structure skeleton rebuilt from the cache, treats every stored slot as a
pattern entry), so everything here is a staging-time constant and a
legitimate plan-cache ``meta`` field / cost-model feature.

Classes (``StructureInfo.structure_class``):

  * ``empty``               no pattern entries at all
  * ``arrow``               dense hub (first block row + column) + diagonal
  * ``banded``              every entry within a narrow scalar band
  * ``partially_diagonal``  a set of dense diagonals covers most entries
  * ``random_block``        none of the above — the generic VBR regime

Classification is a routing *hint*, not a promise: the detector gates
which extra candidates (``dia_hybrid``, reblocking proposals) enter the
measured autotune search, and measurement stays the arbiter.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import vbr as vbrlib

__all__ = [
    "StructureInfo",
    "coo_nonzeros",
    "coo_slots",
    "detect_structure",
    "detect_pattern",
    "BAND_FRAC",
    "DIA_OCCUPANCY",
    "DIA_TOTAL_OCCUPANCY",
    "MAX_DENSE_DIAGS",
    "ARROW_SCORE",
]

# detection knobs (overridable per call; see docs/inspection.md)
BAND_FRAC = 0.25            # bandwidth/max-dim below which a pattern is banded
DIA_OCCUPANCY = 0.5         # per-diagonal fill to count the diagonal as dense
DIA_TOTAL_OCCUPANCY = 0.35  # nnz fraction the dense diagonals must cover
MAX_DENSE_DIAGS = 64        # cap on DIA-hybrid diagonal storage
ARROW_SCORE = 0.85          # hub+diagonal nnz fraction to call it an arrow


@dataclasses.dataclass(frozen=True)
class StructureInfo:
    """Everything detection derives from one scalar sparsity pattern."""

    structure_class: str   # empty|arrow|banded|partially_diagonal|random_block
    nnz: int
    bandwidth: int         # max |col - row| over pattern entries
    bandwidth_frac: float  # bandwidth / max(shape) — scale-free
    diag_occupancy: float  # nnz fraction covered by the dense diagonals
    dense_offsets: tuple   # chosen DIA offsets (col - row), occupancy order
    arrow_score: float     # nnz fraction in hub row/col or diagonal blocks

    @property
    def wants_dia(self) -> bool:
        """Should ``dia_hybrid`` enter the candidate list?  True when the
        dense diagonals exist and cover enough of the pattern that
        scatter-free diagonal compute can plausibly pay for the split."""
        return bool(self.dense_offsets) and (
            self.diag_occupancy >= DIA_TOTAL_OCCUPANCY
        )


def coo_nonzeros(vbr: vbrlib.VBR):
    """Scalar (rows, cols, val_index) of every *pattern* entry.

    Pattern = non-zero stored values; a VBR whose ``val`` is all zeros (a
    structure skeleton from :meth:`~.cache.PlanCache.load_structure`)
    falls back to every stored slot, since the stored-block layout is the
    only pattern information it carries.  Use this for *detection*
    (classifying what the current values look like); anything that builds
    a value gather must use :func:`coo_slots` instead.
    """
    val = np.asarray(vbr.val)
    return _coo(vbr, use_all=val.size == 0 or not np.any(val))


def coo_slots(vbr: vbrlib.VBR):
    """Scalar (rows, cols, val_index) of every STORED slot, zeros included.

    The SABLE contract splits structure from values: a stored zero is a
    live parameter slot whose value may change under the fixed structure.
    Reblocking and DIA-hybrid gathers are *structure* — they must carry
    every slot, or a later value update into a stored-zero slot silently
    vanishes from the staged kernel's output.
    """
    return _coo(vbr, use_all=True)


def _coo(vbr: vbrlib.VBR, use_all: bool):
    rows, cols, vidx = [], [], []
    val = np.asarray(vbr.val)
    for t in vbr.blocks():
        h, w = t.height, t.width
        off = t.val_offset
        local = np.arange(h * w, dtype=np.int64)
        r = t.row_start + (local % h)  # column-major inside the block
        c = t.col_start + (local // h)
        if not use_all:
            keep = val[off : off + h * w] != 0
            local, r, c = local[keep], r[keep], c[keep]
        rows.append(r)
        cols.append(c)
        vidx.append(off + local)
    if not rows:
        z = np.zeros((0,), np.int64)
        return z, z.copy(), z.copy()
    return (
        np.concatenate(rows),
        np.concatenate(cols),
        np.concatenate(vidx),
    )


def _dense_offsets(
    r: np.ndarray,
    c: np.ndarray,
    shape,
    occupancy: float,
    max_diags: int,
):
    """Diagonal offsets (col - row) whose fill exceeds ``occupancy``,
    ordered by entry count (descending) and capped at ``max_diags``."""
    m, k = shape
    d = c - r
    counts = np.bincount(d + (m - 1), minlength=m + k - 1)
    offsets = np.arange(-(m - 1), k, dtype=np.int64)
    # diagonal length: number of valid rows for each offset
    lengths = np.minimum(m, k - offsets) - np.maximum(0, -offsets)
    lengths = np.maximum(lengths, 1)
    occ = counts / lengths
    keep = np.nonzero((occ >= occupancy) & (counts > 0))[0]
    keep = keep[np.argsort(-counts[keep], kind="stable")][:max_diags]
    chosen = offsets[keep]
    covered = int(counts[keep].sum())
    return tuple(int(o) for o in chosen), covered


def detect_structure(
    vbr: vbrlib.VBR,
    *,
    band_frac: float = BAND_FRAC,
    dia_occupancy: float = DIA_OCCUPANCY,
    max_dense_diags: int = MAX_DENSE_DIAGS,
    arrow_score: float = ARROW_SCORE,
) -> StructureInfo:
    """Classify one VBR structure (pure numpy, O(nnz))."""
    r, c, _ = coo_nonzeros(vbr)
    nnz = len(r)
    m, k = vbr.shape
    if nnz == 0:
        return StructureInfo("empty", 0, 0, 0.0, 0.0, (), 0.0)
    bandwidth = int(np.abs(c - r).max())
    bandwidth_frac = bandwidth / max(m, k)
    offsets, covered = _dense_offsets(
        r, c, vbr.shape, dia_occupancy, max_dense_diags
    )
    diag_occ = covered / nnz

    # arrow: hub (first block row + first block column of the GIVEN
    # partition) plus the block diagonal
    h0 = int(vbr.rpntr[1]) if vbr.num_block_rows >= 1 else 0
    w0 = int(vbr.cpntr[1]) if vbr.num_block_cols >= 1 else 0
    br = np.searchsorted(vbr.rpntr, r, side="right") - 1
    bc = np.searchsorted(vbr.cpntr, c, side="right") - 1
    on_arrow = (r < h0) | (c < w0) | (br == bc)
    a_score = float(on_arrow.mean())
    hub = ((r < h0) & (c >= w0)) | ((c < w0) & (r >= h0))

    if (
        a_score >= arrow_score
        and hub.any()
        and min(vbr.num_block_rows, vbr.num_block_cols) >= 3
        and bandwidth_frac > band_frac
    ):
        cls = "arrow"
    elif bandwidth_frac <= band_frac:
        cls = "banded"
    elif diag_occ >= DIA_TOTAL_OCCUPANCY:
        cls = "partially_diagonal"
    else:
        cls = "random_block"
    return StructureInfo(
        structure_class=cls,
        nnz=nnz,
        bandwidth=bandwidth,
        bandwidth_frac=float(bandwidth_frac),
        diag_occupancy=float(diag_occ),
        dense_offsets=offsets,
        arrow_score=a_score,
    )


def detect_pattern(pattern) -> StructureInfo:
    """Classify a ``sparse.linear.BlockPattern`` at tile-grid granularity.

    Tiles live on an R x C grid; coordinates are normalized so rectangular
    grids still have a meaningful diagonal (tile (r, c) is diagonal-band
    when its normalized centers align within one tile pitch).  The
    ``dense_offsets`` field carries the *grid* offsets (only exact for
    square grids); ``wants_dia`` is what ``choose_matmul_strategy`` gates
    its ``dia_hybrid`` candidate on.
    """
    rows = np.asarray(pattern.rows, dtype=np.int64)
    cols = np.asarray(pattern.cols, dtype=np.int64)
    R = max(pattern.d_in // pattern.tm, 1)
    C = max(pattern.d_out // pattern.tk, 1)
    nnz = len(rows)
    if nnz == 0:
        return StructureInfo("empty", 0, 0, 0.0, 0.0, (), 0.0)
    # normalized positions in [0, 1): the scale-free band measure
    rn = (rows + 0.5) / R
    cn = (cols + 0.5) / C
    band = np.abs(cn - rn)
    bandwidth_frac = float(band.max())
    pitch = max(1.0 / R, 1.0 / C)
    on_diag = band <= pitch  # within one tile pitch of the diagonal
    diag_occ = float(on_diag.mean())
    if R == C:
        d = cols - rows
        counts = np.bincount(d + (R - 1), minlength=2 * R - 1)
        offs = np.arange(-(R - 1), R, dtype=np.int64)
        lengths = np.maximum(R - np.abs(offs), 1)
        keep = np.nonzero(counts / lengths >= DIA_OCCUPANCY)[0]
        keep = keep[np.argsort(-counts[keep], kind="stable")][:MAX_DENSE_DIAGS]
        offsets = tuple(int(o) for o in offs[keep])
    else:
        offsets = (0,) if on_diag.any() else ()
    on_arrow = (rows == 0) | (cols == 0) | on_diag
    a_score = float(on_arrow.mean())
    hub = ((rows == 0) & ~on_diag) | ((cols == 0) & ~on_diag)
    if a_score >= ARROW_SCORE and hub.any() and min(R, C) >= 3 and (
        bandwidth_frac > BAND_FRAC
    ):
        cls = "arrow"
    elif bandwidth_frac <= BAND_FRAC:
        cls = "banded"
    elif diag_occ >= DIA_TOTAL_OCCUPANCY:
        cls = "partially_diagonal"
    else:
        cls = "random_block"
    bandwidth = int(round(bandwidth_frac * max(pattern.d_in, pattern.d_out)))
    return StructureInfo(
        structure_class=cls,
        nnz=nnz,
        bandwidth=bandwidth,
        bandwidth_frac=bandwidth_frac,
        diag_occupancy=diag_occ,
        dense_offsets=offsets,
        arrow_score=a_score,
    )
