"""SABLE core: VBR format, staged DSL, Stage-0/1 compiler."""
from .vbr import VBR, BlockTask, from_dense, structure_hash, synthesize, synthesize_paper
from .dsl import ArrayVal, ConcreteArrayVal, RepRange, isDense, loopgen, stage_op
from .ops_dsl import ArrayView, spmm_op, spmv_op
from .backends import BlockMatmul, match_block_matmul, run_reference, run_vectorized
from .staging import (
    StagedKernel,
    StagingOptions,
    cache_info,
    clear_cache,
    partition_block_rows,
    stage_block_op,
    stage_spmm,
    stage_spmv,
)
from .sharded import ShardedStagedKernel, resolve_model_axis, resolve_shard_axis
from .uniformize import TiledPattern, uniformize
from .cache import PlanCache, TuningPlan, default_cache, plan_key, set_default_cache
# NB: the bare `autotune` function is NOT re-exported — it would shadow the
# `repro.core.autotune` submodule; use `from repro.core.autotune import autotune`.
from .autotune import (
    autotune_stage,
    autotune_stats,
    candidate_options,
    reset_autotune_stats,
    tune_num_workers,
)
from .cost_model import CostModel, cost_model_stats, load_or_fit, reset_cost_model_stats
# NB: `inspect` and `reblock` are submodule imports only — re-exporting the
# bare `detect_structure`/`propose_reblockings` names is fine, but the
# modules themselves must stay addressable as `repro.core.inspect` /
# `repro.core.reblock` (docs link to them by dotted path).
from .inspect import StructureInfo, detect_pattern, detect_structure
from .reblock import (
    ReblockSpec,
    apply_reblock,
    propose_reblockings,
    reblock_stats,
    reset_reblock_stats,
    stage_reblocked,
)
