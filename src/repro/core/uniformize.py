"""Tile uniformization: variable VBR blocks -> fixed MXU-aligned tiles.

This is the central hardware adaptation (DESIGN.md Section 2).  The paper's
Stage-1 emits one C loop nest per variable-size block; a TPU wants ONE
regular grid over uniform tiles.  At staging time we:

  1. lay the block rows/columns out in a *padded* coordinate space where
     every block row/column is rounded up to the tile size,
  2. split every stored VBR block into (tm x tk) tiles, recording for each
     tile its padded-space row/col tile index and a gather map back into
     the runtime ``val`` array (sentinel index -> 0 for padding),
  3. add zero 'coverage' tiles so every padded output row-tile is visited
     at least once (the kernel initializes on first visit),
  4. sort tiles row-major so the Pallas grid accumulates each output block
     over consecutive steps.

Padding entries are literally 'computing over some zeros' — the paper's
trade applied a second time at the tile level.  All arrays produced here
are structure (static); only ``val`` stays runtime.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .backends import BlockMatmul

__all__ = ["TiledPattern", "uniformize"]


@dataclasses.dataclass
class TiledPattern:
    """Static tile tables for the Pallas kernels + pack/unpack maps."""

    tm: int
    tk: int
    n_tiles: int
    # (n_tiles,) padded-space tile coordinates, sorted by (row, col)
    row_ids: np.ndarray
    col_ids: np.ndarray
    # (n_tiles, tm*tk) gather map into val (+1 shifted; 0 means padding zero)
    val_gather: np.ndarray
    # padded sizes and scatter/gather maps between real and padded coords
    m_pad: int
    k_pad: int
    x_src: np.ndarray  # (k_pad,) index into x (+1 shifted; 0 -> zero)
    y_src: np.ndarray  # (m,) index into padded y
    m: int
    k: int

    @property
    def padded_fraction(self) -> float:
        """Fraction of tile entries that are padding (wasted MXU work)."""
        return float((self.val_gather == 0).mean())


def _ceil_to(x: int, t: int) -> int:
    return -(-x // t) * t


def uniformize(
    descs: list[BlockMatmul],
    m: int,
    k: int,
    row_splits: np.ndarray,
    col_splits: np.ndarray,
    tm: int,
    tk: int,
) -> TiledPattern:
    """Stage-0 tile packing.  ``descs`` are the matched per-block matmuls;
    ``row_splits``/``col_splits`` are rpntr/cpntr of the VBR structure."""
    row_splits = np.asarray(row_splits)
    col_splits = np.asarray(col_splits)
    R = len(row_splits) - 1
    C = len(col_splits) - 1

    # padded offsets per block row / block col
    row_pad_off = np.zeros(R + 1, dtype=np.int64)
    for a in range(R):
        h = int(row_splits[a + 1] - row_splits[a])
        row_pad_off[a + 1] = row_pad_off[a] + _ceil_to(h, tm)
    col_pad_off = np.zeros(C + 1, dtype=np.int64)
    for b in range(C):
        w = int(col_splits[b + 1] - col_splits[b])
        col_pad_off[b + 1] = col_pad_off[b] + _ceil_to(w, tk)
    m_pad = int(row_pad_off[-1])
    k_pad = int(col_pad_off[-1])

    # x scatter map: padded coord -> source coord (+1; 0 = zero fill)
    x_src = np.zeros(k_pad, dtype=np.int64)
    for b in range(C):
        c0, c1 = int(col_splits[b]), int(col_splits[b + 1])
        p0 = int(col_pad_off[b])
        x_src[p0 : p0 + (c1 - c0)] = np.arange(c0, c1) + 1
    # y gather map: real row -> padded row
    y_src = np.zeros(m, dtype=np.int64)
    for a in range(R):
        r0, r1 = int(row_splits[a]), int(row_splits[a + 1])
        p0 = int(row_pad_off[a])
        y_src[r0:r1] = np.arange(p0, p0 + (r1 - r0))

    row_of = {int(row_splits[a]): a for a in range(R)}
    col_of = {int(col_splits[b]): b for b in range(C)}

    tiles: list[tuple[int, int, np.ndarray]] = []
    rr_idx = np.arange(tm)
    cc_idx = np.arange(tk)
    for d in descs:
        a = row_of[d.row_start]
        b = col_of[d.col_start]
        h, w = d.h, d.w
        n_ti = -(-h // tm)
        n_tj = -(-w // tk)
        base_rt = int(row_pad_off[a]) // tm
        base_ct = int(col_pad_off[b]) // tk
        for ti in range(n_ti):
            for tj in range(n_tj):
                rows = ti * tm + rr_idx  # intra-block row
                cols = tj * tk + cc_idx  # intra-block col
                valid = (rows[:, None] < h) & (cols[None, :] < w)
                # col-major inside the block: idx = col*h + row
                g = d.val_off + cols[None, :] * h + rows[:, None]
                g = np.where(valid, g + 1, 0)  # +1 shift; 0 => padding zero
                tiles.append((base_rt + ti, base_ct + tj, g.reshape(-1)))

    # coverage: every output row tile must be visited at least once
    covered = {t[0] for t in tiles}
    zero_g = np.zeros(tm * tk, dtype=np.int64)
    for rt in range(m_pad // tm):
        if rt not in covered:
            tiles.append((rt, 0, zero_g))

    tiles.sort(key=lambda t: (t[0], t[1]))
    row_ids = np.asarray([t[0] for t in tiles], dtype=np.int32)
    col_ids = np.asarray([t[1] for t in tiles], dtype=np.int32)
    val_gather = np.stack([t[2] for t in tiles]).astype(np.int64)
    return TiledPattern(
        tm=tm,
        tk=tk,
        n_tiles=len(tiles),
        row_ids=row_ids,
        col_ids=col_ids,
        val_gather=val_gather,
        m_pad=m_pad,
        k_pad=k_pad,
        x_src=x_src,
        y_src=y_src,
        m=m,
        k=k,
    )
