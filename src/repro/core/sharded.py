"""Sharded staged execution: per-shard staged kernels over a device mesh.

The paper's parallel results split staged block work across workers; this
module is the multi-device version of that split for JAX.  A
:class:`~repro.distributed.partition.ShardPlan` cuts the VBR block rows
into nnz-balanced shards, each shard is staged as its OWN specialized
kernel (so a shard only instantiates kernels for its local block-size
distribution — shard-local staging), and execution runs either:

  * ``shard_map`` SPMD path (``mesh=`` given): one program over the
    ``"shards"`` mesh axis; each device selects its shard's specialized
    sub-program by ``lax.axis_index`` (``lax.switch`` over the staged
    branches).  Values/outputs carry explicit sharding constraints, so the
    SPMD partitioner never has to guess a layout (no involuntary
    rematerialization of the gathered tiles).
  * host loop (no mesh): the per-shard kernels run sequentially and
    scatter into the global output — the reference semantics used by the
    equivalence tests.

2-D (shards x model) meshes: when the mesh also carries a ``"model"``
axis, the dense SpMM operand is column-partitioned over it — device
``(i, j)`` computes shard ``i``'s rows for the ``j``-th column slice, so
the staged sparse kernels compose with tensor-parallel models (the RHS
arrives already model-sharded from a TP layer and the output stays
model-sharded).  Each shard then stages for its LOCAL column count and
its tuning plan is keyed by ``model_cols`` on top of the shard id.

Gather/compute overlap: by default (``overlap_gather=True``) the y-gather
over the shard axis runs as a ``ppermute`` ring INSIDE ``shard_map``
instead of a trailing XLA all-gather.  A trailing all-gather is a barrier
— every device waits for the slowest shard before any result bytes move.
In the ring, a shard that finishes early starts forwarding its output
tile immediately, so gather traffic overlaps with the still-running
shards' compute (XLA lowers the ring steps to async
collective-permute-start/done pairs).

Per-shard tuning plans are persisted keyed by
``(parent structure_hash, device, shard_id[, model_cols])`` via
``core.cache.plan_key`` (``backend='autotune'``), so a restarted server
stages every shard with zero re-benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import staging as staginglib
from . import vbr as vbrlib
from .cache import default_cache, plan_key
from .staging import StagingOptions

__all__ = ["ShardedStagedKernel", "resolve_shard_axis", "resolve_model_axis"]


def resolve_shard_axis(mesh, shard_axis: str = "shards") -> str:
    """Pick the mesh axis shards live on: ``shard_axis`` when present, the
    sole axis of a 1-D mesh otherwise."""
    if shard_axis in mesh.axis_names:
        return shard_axis
    if len(mesh.axis_names) == 1:
        return mesh.axis_names[0]
    raise ValueError(
        f"mesh {mesh.axis_names} has no {shard_axis!r} axis; build one with "
        "launch.mesh.make_staging_mesh or pass shard_axis="
    )


def resolve_model_axis(mesh, model_axis: str = "model") -> Optional[str]:
    """The mesh axis the dense operand's columns are partitioned over, or
    None when the mesh has no such axis (pure 1-D sharded staging)."""
    return model_axis if model_axis in mesh.axis_names else None


def _shard_options(
    kind: str,
    parent_hash: str,
    shard,
    base_opts: StagingOptions,
    n_cols,
    cache,
    model_cols=None,
) -> StagingOptions:
    """Resolve the staging options for ONE shard.  'autotune' tunes (or
    loads) a per-shard plan keyed by the parent hash + shard id (+ the
    local column count on a 2-D mesh)."""
    if base_opts.backend != "autotune":
        return base_opts
    from .autotune import autotune

    device = jax.default_backend()
    key = plan_key(
        kind,
        parent_hash,
        device,
        n_cols,
        shard_id=shard.shard_id,
        num_shards=shard.num_shards,
        model_cols=model_cols,
    )
    store = cache if cache is not None else default_cache()
    plan = store.load_plan(key)
    if plan is None:
        # tunes on the shard-local structure (also cached under the shard's
        # own sub-structure hash — two matrices sharing a shard pattern
        # share the plan)
        tune_cols = model_cols if model_cols is not None else n_cols
        plan = autotune(shard.vbr, kind, tune_cols, cache=store)
        plan = dataclasses.replace(
            plan,
            meta={
                **plan.meta,
                "parent_structure_hash": parent_hash,
                "shard_id": shard.shard_id,
                "num_shards": shard.num_shards,
                **({} if model_cols is None else {"model_cols": model_cols}),
            },
        )
        store.store_plan(key, plan)
    return dataclasses.replace(
        plan.options, dtype=base_opts.dtype, interpret=base_opts.interpret
    )


class ShardedStagedKernel:
    """Sharded counterpart of :class:`~repro.core.staging.StagedKernel`:
    ``fn(val, x) -> y`` where ``val`` is the GLOBAL value array and ``y``
    the global output; the block-row split (and, on a 2-D mesh, the model
    column split) is internal."""

    def __init__(
        self,
        kind: str,
        vbr: vbrlib.VBR,
        opts: StagingOptions = StagingOptions(),
        *,
        num_shards: Optional[int] = None,
        mesh=None,
        shard_axis: str = "shards",
        model_axis: str = "model",
        strategy: str = "lpt",
        n_cols: Optional[int] = None,
        hints: Optional[np.ndarray] = None,
        cache=None,
        use_cached_plan: bool = True,
        overlap_gather: bool = True,
    ):
        from ..distributed.partition import (
            load_shard_plan,
            make_shard_plan,
            save_shard_plan,
        )

        t0 = time.perf_counter()
        self.model_axis = None
        self.model_size = 1
        if mesh is not None:
            self.axis = resolve_shard_axis(mesh, shard_axis)
            self.model_axis = resolve_model_axis(mesh, model_axis)
            if self.model_axis == self.axis:
                self.model_axis = None
            if self.model_axis is not None:
                self.model_size = int(mesh.shape[self.model_axis])
            mesh_n = int(mesh.shape[self.axis])
            if num_shards is None:
                num_shards = mesh_n
            elif num_shards != mesh_n:
                raise ValueError(
                    f"shards={num_shards} != mesh axis {self.axis!r} size {mesh_n}"
                )
        elif num_shards is None:
            raise ValueError("need mesh= or shards=")
        else:
            self.axis = shard_axis
        if opts.prepack:
            raise ValueError("prepack is not supported for sharded staging")

        # 2-D mesh: the model axis column-partitions the SpMM RHS, so each
        # shard stages (and autotunes) for its LOCAL column count
        self.local_cols = n_cols
        if kind == "spmm" and self.model_size > 1:
            if n_cols is None or n_cols % self.model_size != 0:
                raise ValueError(
                    f"n_cols={n_cols} must divide evenly over the "
                    f"{self.model_axis!r} axis (size {self.model_size})"
                )
            self.local_cols = n_cols // self.model_size

        self.kind = kind
        self.opts = opts
        self.mesh = mesh
        self.overlap_gather = overlap_gather
        self.m, self.k = vbr.shape
        self.n_cols = n_cols
        self.structure_hash = vbrlib.structure_hash(vbr)
        self.plan = None
        if use_cached_plan:
            self.plan = load_shard_plan(vbr, num_shards, strategy, cache=cache)
        if self.plan is None:
            self.plan = make_shard_plan(vbr, num_shards, strategy)
            if use_cached_plan:
                save_shard_plan(self.plan, cache=cache)
        self.num_shards = num_shards

        # shard-local staging: each shard compiles kernels only for its own
        # block-size distribution (the in-memory executable cache dedups
        # shards that happen to share a pattern)
        model_cols = self.local_cols if self.model_size > 1 else None
        self.kernels = []
        for s in self.plan.shards:
            s_opts = _shard_options(
                kind, self.structure_hash, s, opts, n_cols, cache,
                model_cols=model_cols,
            )
            s_hints = hints[s.val_index] if hints is not None else None
            if s_opts.density_threshold > 0 and s_hints is None:
                s_hints = s.vbr.val
            self.kernels.append(
                staginglib._cached(
                    kind, s.vbr, s_opts, s_hints, n_cols=self.local_cols
                )
            )
        self.num_blocks = sum(s.vbr.num_blocks for s in self.plan.shards)

        self._build_maps()
        self._fn = jax.jit(
            self._build_spmd() if mesh is not None else self._build_host()
        )
        self.stage0_time = time.perf_counter() - t0
        self.compile_time = 0.0

    # ------------------------------------------------------------------ #
    def _build_maps(self) -> None:
        shards = self.plan.shards
        D = self.num_shards
        self.max_nnz = max((s.nnz for s in shards), default=0)
        self.max_rows = max((s.local_m for s in shards), default=0)
        # (D, max_nnz) gather map into 1-shifted global val (0 = pad zero)
        vg = np.zeros((D, max(self.max_nnz, 1)), dtype=np.int64)
        for s in shards:
            vg[s.shard_id, : s.nnz] = s.val_index + 1
        self.val_gather = vg.astype(np.int32)
        # (m,) gather from 1-shifted flattened padded outputs (0 = zero)
        ys = np.zeros((self.m,), dtype=np.int64)
        for s in shards:
            local = np.arange(s.local_m, dtype=np.int64)
            ys[s.row_index] = s.shard_id * max(self.max_rows, 1) + local + 1
        self.y_src = ys.astype(np.int32)

    # ------------------------------------------------------------------ #
    def _build_host(self):
        shards, kernels, kind = self.plan.shards, self.kernels, self.kind

        def fn(val, x):
            y = jnp.zeros(self._out_shape(x), dtype=x.dtype)
            for s, kern in zip(shards, kernels):
                if s.nnz == 0 and s.vbr.num_blocks == 0:
                    continue
                ys = kern(val[jnp.asarray(s.val_index)], x)
                y = y.at[jnp.asarray(s.row_index)].set(ys.astype(x.dtype))
            return y

        del kind
        return fn

    def _build_spmd(self):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh, axis = self.mesh, self.axis
        shards, kernels = self.plan.shards, self.kernels
        kind = self.kind
        D, max_rows = self.num_shards, self.max_rows
        val_gather = self.val_gather
        y_src = self.y_src
        # model axis column split applies to the SpMM RHS only (SpMV's x
        # is a vector — it replicates across the model axis)
        col_axis = (
            self.model_axis
            if (kind == "spmm" and self.model_size > 1)
            else None
        )
        overlap = self.overlap_gather and D > 1

        def branch_for(s, kern):
            def br(vs, x):
                v = vs[0, : max(s.nnz, 1)][: s.nnz]
                ys = kern(v, x).astype(x.dtype)
                pad = max_rows - s.local_m
                if pad:
                    ys = jnp.concatenate(
                        [ys, jnp.zeros((pad,) + ys.shape[1:], ys.dtype)]
                    )
                return ys

            return br

        branches = [branch_for(s, k) for s, k in zip(shards, kernels)]
        ring = [(j, (j + 1) % D) for j in range(D)]

        def local(vs, x):
            i = jax.lax.axis_index(axis)
            ys = jax.lax.switch(i, branches, vs, x)  # (max_rows[, nloc])
            if not overlap:
                return ys[None]
            # ppermute ring all-gather over the shard axis: shard i's tile
            # reaches every device in D-1 hops.  A shard that finishes
            # early forwards immediately, so its gather traffic overlaps
            # with slower shards' compute — no barrier all-gather.
            buf = jnp.zeros((D,) + ys.shape, ys.dtype).at[i].set(ys)
            cur = ys
            for t in range(1, D):
                cur = jax.lax.ppermute(cur, axis, ring)
                buf = buf.at[(i - t) % D].set(cur)
            # reassemble the full output locally (pure data movement —
            # row spans are disjoint, there is no cross-shard reduction)
            flat = buf.reshape((D * max_rows,) + ys.shape[1:])
            z = jnp.zeros((1,) + flat.shape[1:], flat.dtype)
            return jnp.concatenate([z, flat])[jnp.asarray(y_src)]

        x_parts = (None,) if kind == "spmv" else (None, col_axis)
        if overlap:
            out_specs = P(None, *x_parts[1:])  # assembled in-ring
        else:
            out_specs = P(axis, *x_parts)
        mapped = shard_map(
            local, mesh=mesh, in_specs=(P(axis, None), P(*x_parts)),
            out_specs=out_specs, check_rep=False,
        )

        def fn(val, x):
            # explicit layouts end-to-end: the tile gather lands directly
            # in the (shards, nnz) sharded layout and x arrives replicated
            # over shards (and column-split over the model axis on a 2-D
            # mesh) — nothing is left for the partitioner to rematerialize.
            val1 = jnp.concatenate([jnp.zeros((1,), val.dtype), val])
            vp = val1[jnp.asarray(val_gather)]
            vp = jax.lax.with_sharding_constraint(
                vp, NamedSharding(mesh, P(axis, None))
            )
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*x_parts))
            )
            if overlap:
                y = mapped(vp, x)  # (m[, n]) — gathered inside the ring
            else:
                yp = mapped(vp, x)  # (D, max_rows[, n])
                # replicate BEFORE the reshape: reshaping across the
                # sharded dim on a 2-D mesh trips an XLA SPMD partitioner
                # miscompile (output scaled by model_size^2 — same family
                # as the PR-3 involuntary-remat bugs); an explicit
                # all-gather here keeps the partitioner out of the
                # reshape/gather chain entirely
                yp = jax.lax.with_sharding_constraint(
                    yp, NamedSharding(mesh, P(None, None, *x_parts[1:]))
                )
                flat = yp.reshape((D * max_rows,) + yp.shape[2:])
                z = jnp.zeros((1,) + flat.shape[1:], flat.dtype)
                y = jnp.concatenate([z, flat])[jnp.asarray(y_src)]
            # rows replicated; SpMM columns stay model-sharded so the
            # output feeds a tensor-parallel consumer without a reshard
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, P(None, *x_parts[1:]))
            )

        return fn

    # ------------------------------------------------------------------ #
    def _out_shape(self, x):
        return (self.m,) if self.kind == "spmv" else (self.m, x.shape[1])

    def __call__(self, val, x):
        return self._fn(val, x)

    def compile(self, val_spec, x_spec) -> "ShardedStagedKernel":
        t0 = time.perf_counter()
        self._fn = self._fn.lower(val_spec, x_spec).compile()
        self.compile_time = time.perf_counter() - t0
        return self

    @property
    def inspection_time(self) -> float:
        return self.stage0_time + self.compile_time

    def imbalance(self) -> float:
        return self.plan.imbalance()
