"""The user-level ops of the paper, written in the SABLE DSL.

These are verbatim ports of Section IV-B (SpMV) and IV-C (SpMM): the user
has fine-grained control over loop order via the nesting of ``loopgen``
calls; SABLE does no auto-reordering (paper Section IV-B).
"""
from __future__ import annotations

from .dsl import ArrayVal, LinExpr, RepRange, loopgen

__all__ = ["ArrayView", "spmv_op", "spmm_op"]


class ArrayView(ArrayVal):
    """A view of an array at a static offset (the block's slice of ``val``).

    The paper passes ``val[indx[count]]`` as the block's base; we keep the
    global array and bake the offset into every index (Listing 2 indexes
    ``val[69722 + ...]``)."""

    def __init__(self, base: ArrayVal, offset: int):
        super().__init__(base.name)
        self.base = base
        self.offset = int(offset)

    def __getitem__(self, idx):
        return self.base[LinExpr.of(idx) + self.offset]

    def __setitem__(self, idx, value):
        self.base[LinExpr.of(idx) + self.offset] = value


def spmv_op(
    row_idxs: RepRange,
    col_idxs: RepRange,
    col_maj_val: ArrayVal,  # dense block from vbr
    x: ArrayVal,  # dense vector to multiply
    y: ArrayVal,  # output
):
    """Paper Section IV-B.  Loop order: j outer, i inner (vectorizable)."""

    def op(j, i):
        row = i - row_idxs.start
        col = j - col_idxs.start
        m_val = col_maj_val[col * len(row_idxs) + row]
        y[i] += m_val * x[j]

    return loopgen(col_idxs, lambda j: loopgen(row_idxs, lambda i: op(j, i)))


def spmm_op(
    row_idxs: RepRange,
    col_idxs: RepRange,
    dense_idxs: RepRange,
    col_maj_val: ArrayVal,  # dense block from vbr
    x: ArrayVal,  # dense matrix to multiply (row-major, col_width columns)
    y: ArrayVal,  # output (row-major, col_width columns)
):
    """Paper Section IV-C.  j innermost so the compiler vectorizes over the
    dense columns."""
    col_width = len(dense_idxs)

    def op(i, k, j):
        row = i - row_idxs.start
        col = k - col_idxs.start
        m_val = col_maj_val[col * len(row_idxs) + row]
        y[i * col_width + j] += m_val * x[k * col_width + j]

    return loopgen(
        row_idxs,
        lambda i: loopgen(
            col_idxs, lambda k: loopgen(dense_idxs, lambda j: op(i, k, j))
        ),
    )
