"""Learned cost model over the plan-cache corpus: predict backend winners.

The autotuner (``core/autotune.py``) decides everything structure-derivable
once per pattern — but it decides by *measuring* every backend candidate,
and at production cardinality (millions of distinct routing/serving
structures) that cold-start staging cost is the bottleneck.  This module
closes the loop the ROADMAP asks for: every measured ``TuningPlan`` already
persisted by :class:`~.cache.PlanCache` is a labeled training example
(structure features x device -> per-backend runtime), so a process that has
tuned enough structures can *predict* the winner for a new one and skip the
micro-benchmarks entirely.

Design (pure numpy, no new dependencies):

* **Features** (:func:`meta_features`) come from the plan's ``meta`` dict —
  rows/cols, stored nnz, block count, block-size moments, density, and the
  dense-operand column count — log-scaled so ridge regression over
  log-runtime sees roughly linear structure (runtime of every backend here
  is polynomial in the size quantities).
* **Model** (:class:`CostModel`): one closed-form ridge regressor per
  candidate *label* (``grouped``, ``bucketed``, ``pallas[8x128]``, ...)
  over z-scored features, fit per ``(device, kind)`` — a TPU model never
  answers for CPU.  The z-scored training set is retained for a
  nearest-neighbor distance, which is the out-of-distribution gate.
* **Calibrated refusal**: prediction is only trusted when (a) every
  candidate label was seen in training, (b) the nearest corpus structure is
  within :data:`DEFAULT_MAX_DISTANCE` in z-space, and (c) the predicted
  gap between the top two candidates exceeds :data:`DEFAULT_MARGIN`.
  Anything else falls back to measurement — the measurement path stays the
  ground-truth oracle, and what it measures is recorded back into the
  corpus, so the model improves online and every prediction stays testable
  against a measurable truth.
* **Persistence**: fitted models are stored in the same cache under
  ``models/cost-<kind>-<device>-v<version>.json`` and refit automatically
  once the corpus grows past :data:`REFIT_GROWTH` x the size it was
  trained on (:func:`load_or_fit`).

``autotune(mode="predict")`` and
``sparse.linear.choose_matmul_strategy(mode="predict")`` are the two
consumers; ``serve/scheduler.py`` additionally uses the model to *score*
cold structures by predicted staging cost (cheapest-first admission)
instead of treating all cold requests as equally expensive.
"""
from __future__ import annotations

import math
from typing import Iterable, Optional

import numpy as np

from .cache import PlanCache, TuningPlan

__all__ = [
    "COST_MODEL_VERSION",
    "CostModel",
    "corpus",
    "cost_model_stats",
    "fit",
    "load_or_fit",
    "meta_features",
    "model_key",
    "pattern_features",
    "reset_cost_model_stats",
    "vbr_features",
    "FEATURE_NAMES",
]

# v2: structure-class features (bandwidth_frac / diag_occupancy /
# reblock_fill) joined FEATURE_NAMES — the bump orphans v1 models by key
# so they refit instead of replaying weights over a different feature set
COST_MODEL_VERSION = 2

# calibration knobs (overridable per call)
MIN_CORPUS = 8          # plans needed before a model is fit at all
MIN_LABEL_SAMPLES = 3   # timings needed before a label's regressor answers
RIDGE_LAMBDA = 1e-3
DEFAULT_MARGIN = 0.15        # required relative gap between top-2 predictions
DEFAULT_MAX_DISTANCE = 2.0   # required z-space RMS distance to nearest neighbor
REFIT_GROWTH = 1.5           # refit when corpus grows past this factor
MAX_TRAIN_ROWS = 1024        # cap on retained z-scored rows (OOD gate)

FEATURE_NAMES = (
    "log_rows",
    "log_cols",
    "log_nnz",
    "log_blocks",
    "log_block_mean",
    "log_block_max",
    "block_cv",
    "density",
    "log_n_cols",
    # structure-class features (core/inspect.py / core/reblock.py): these
    # separate the patterns where dia_hybrid / reblocked candidates win
    "bandwidth_frac",   # scalar bandwidth / max dim (1.0 when unrecorded)
    "diag_occupancy",   # nnz fraction on dense diagonals (0.0 default)
    "reblock_fill",     # fill ratio of the primary reblocking proposal
)

_STATS = {
    "model_fits": 0,
    "model_loads": 0,
    "plans_predicted": 0,
    "predict_fallbacks": 0,
}


def cost_model_stats() -> dict:
    return dict(_STATS)


def reset_cost_model_stats() -> None:
    _STATS.update({k: 0 for k in _STATS})


# ---------------------------------------------------------------------- #
# feature extraction
# ---------------------------------------------------------------------- #
def meta_features(kind: str, meta: dict, n_cols=None) -> np.ndarray:
    """Fixed-length feature vector from a plan's ``meta`` dict.

    Handles both the VBR autotuner's meta (``autotune._structure_meta``)
    and the ``linear`` kind's BlockPattern meta.  Old plans written before
    the block-moment fields existed degrade gracefully (moments derived
    from nnz / block count).
    """
    if kind == "linear":
        rows = float(meta["d_in"])
        cols = float(meta["d_out"])
        nb = float(meta["n_tiles"])
        bsize = float(meta["tm"]) * float(meta["tk"])
        nnz = nb * bsize
        bmean = bmax = bsize
        bcv = 0.0
        density = float(meta.get("density", 1.0))
    else:
        rows, cols = (float(s) for s in meta["shape"])
        nnz = float(meta["stored_nnz"])
        nb = float(meta["num_blocks"])
        bmean = float(meta.get("block_size_mean", nnz / max(nb, 1.0)))
        bmax = float(meta.get("block_size_max", bmean))
        bcv = float(meta.get("block_size_cv", 0.0))
        density = float(meta.get("density", 1.0))
    nc = 1.0 if n_cols is None else float(n_cols)
    # structure-class features degrade gracefully on pre-v2 metas: a full
    # band (1.0), no dense diagonals (0.0), no reblocking fill (1.0)
    band_frac = float(meta.get("bandwidth_frac", 1.0))
    diag_occ = float(meta.get("diag_occupancy", 0.0))
    reblock_fill = float(meta.get("reblock_fill_ratio", 1.0))
    return np.array(
        [
            math.log1p(rows),
            math.log1p(cols),
            math.log1p(nnz),
            math.log1p(nb),
            math.log1p(bmean),
            math.log1p(bmax),
            bcv,
            density,
            math.log1p(nc),
            band_frac,
            diag_occ,
            reblock_fill,
        ],
        dtype=np.float64,
    )


def plan_features(plan: TuningPlan) -> np.ndarray:
    return meta_features(plan.kind, plan.meta, plan.n_cols)


def vbr_features(vbr, kind: str = "spmv", n_cols=None) -> np.ndarray:
    """Features for a VBR structure not yet in the corpus."""
    from .autotune import _structure_meta

    return meta_features(kind, _structure_meta(vbr), n_cols)


def pattern_features(pattern) -> np.ndarray:
    """Features for a ``sparse.linear.BlockPattern`` (kind ``linear``)."""
    return meta_features(
        "linear",
        {
            "d_in": pattern.d_in,
            "d_out": pattern.d_out,
            "tm": pattern.tm,
            "tk": pattern.tk,
            "n_tiles": pattern.n_tiles,
            "density": pattern.density,
        },
    )


# ---------------------------------------------------------------------- #
# the model
# ---------------------------------------------------------------------- #
class CostModel:
    """Per-(device, kind) runtime predictor: one ridge regressor per
    candidate label over z-scored features, log-runtime target, plus the
    retained training rows for the nearest-neighbor OOD gate."""

    def __init__(
        self,
        device: str,
        kind: str,
        mu: np.ndarray,
        sigma: np.ndarray,
        weights: dict,       # label -> (F+1,) ridge weights (bias first)
        label_counts: dict,  # label -> training-sample count
        train_x: np.ndarray,  # (N, F) z-scored corpus features
        n_train: int,
        version: int = COST_MODEL_VERSION,
    ):
        self.device = device
        self.kind = kind
        self.mu = np.asarray(mu, np.float64)
        self.sigma = np.asarray(sigma, np.float64)
        self.weights = {k: np.asarray(v, np.float64) for k, v in weights.items()}
        self.label_counts = dict(label_counts)
        self.train_x = np.asarray(train_x, np.float64).reshape(-1, len(mu))
        self.n_train = int(n_train)
        self.version = int(version)

    # ------------------------------------------------------------------ #
    def _z(self, feats: np.ndarray) -> np.ndarray:
        return (np.asarray(feats, np.float64) - self.mu) / self.sigma

    def knows(self, label: str) -> bool:
        return self.label_counts.get(label, 0) >= MIN_LABEL_SAMPLES

    def predict(self, feats: np.ndarray, labels: Iterable[str]) -> dict:
        """Predicted runtime (seconds) per label; unknown labels omitted."""
        z = self._z(feats)
        zb = np.concatenate([[1.0], z])
        out = {}
        for label in labels:
            if self.knows(label):
                out[label] = float(np.exp(zb @ self.weights[label]))
        return out

    def rank(self, feats: np.ndarray, labels: Iterable[str]) -> list:
        preds = self.predict(feats, labels)
        return sorted(preds.items(), key=lambda kv: kv[1])

    def margin(self, feats: np.ndarray, labels: Iterable[str]) -> float:
        """Relative gap between the top-2 predicted candidates (inf when
        only one candidate is rankable)."""
        ranked = self.rank(feats, labels)
        if len(ranked) < 2:
            return float("inf")
        (_, t1), (_, t2) = ranked[0], ranked[1]
        return (t2 - t1) / max(t1, 1e-12)

    def nn_distance(self, feats: np.ndarray) -> float:
        """RMS z-space distance to the nearest training structure."""
        if not len(self.train_x):
            return float("inf")
        d = self.train_x - self._z(feats)[None, :]
        return float(np.sqrt((d * d).mean(axis=1)).min())

    def staging_cost(self, feats: np.ndarray, labels=None) -> float:
        """Predicted cost of *measuring* this structure: the sum of every
        known candidate's predicted runtime (the tuner stages and times
        them all).  Used by the scheduler to order cold structures."""
        preds = self.predict(
            feats, labels if labels is not None else self.weights
        )
        return float(sum(preds.values())) if preds else float("inf")

    def confident(
        self,
        feats: np.ndarray,
        labels: Iterable[str],
        margin: float = DEFAULT_MARGIN,
        max_distance: float = DEFAULT_MAX_DISTANCE,
    ) -> tuple:
        """(ok, reason) — ok only when prediction is trustworthy enough to
        skip measurement.  Never-guess contract: any unknown candidate
        label, an out-of-corpus feature vector, or a too-close call
        returns ``(False, reason)`` and the caller measures."""
        labels = list(labels)
        unknown = [lbl for lbl in labels if not self.knows(lbl)]
        if unknown:
            return False, f"unknown candidates {unknown}"
        d = self.nn_distance(feats)
        if d > max_distance:
            return False, f"out of corpus (nn distance {d:.2f} > {max_distance})"
        m = self.margin(feats, labels)
        if m < margin:
            return False, f"margin {m:.3f} < {margin}"
        return True, f"margin {m:.3f}, nn distance {d:.2f}"

    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        return {
            "version": self.version,
            "device": self.device,
            "kind": self.kind,
            "feature_names": list(FEATURE_NAMES),
            "mu": self.mu.tolist(),
            "sigma": self.sigma.tolist(),
            "weights": {k: v.tolist() for k, v in self.weights.items()},
            "label_counts": dict(self.label_counts),
            "train_x": self.train_x.tolist(),
            "n_train": self.n_train,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CostModel":
        if d.get("version") != COST_MODEL_VERSION:
            raise ValueError(f"unsupported cost-model version {d.get('version')}")
        if tuple(d.get("feature_names", ())) != FEATURE_NAMES:
            raise ValueError("cost-model feature set drifted; refit")
        return cls(
            device=d["device"],
            kind=d["kind"],
            mu=np.asarray(d["mu"]),
            sigma=np.asarray(d["sigma"]),
            weights=d["weights"],
            label_counts=d["label_counts"],
            train_x=np.asarray(d["train_x"]),
            n_train=d["n_train"],
            version=d["version"],
        )


# ---------------------------------------------------------------------- #
# fitting
# ---------------------------------------------------------------------- #
def corpus(
    cache: PlanCache, device: str, kind: str
) -> list:
    """Every *measured* plan for (device, kind) in the cache — predicted
    and heuristic plans are excluded so the model never trains on its own
    output (no feedback loop).

    Reblocked plans are additionally excluded UNLESS their structure meta
    carries the reblock features (``reblock_fill_ratio``): a reblocked
    plan's timings were measured over a different (reblocked) layout, so
    training on it against features that don't describe that reblocking
    would be the same no-feedback-loop violation — the features and the
    label would silently disagree.  Plans written by this version always
    carry the feature; the guard protects against plans written by
    foreign/older writers.
    """
    return [
        p
        for p in cache.iter_plans(device=device, kind=kind)
        if p.source == "measured"
        and p.timings
        and not (p.reblock is not None and "reblock_fill_ratio" not in p.meta)
    ]


def fit(plans: list, device: str, kind: str) -> Optional[CostModel]:
    """Closed-form ridge fit over the corpus; None if it is too small."""
    plans = [p for p in plans if p.timings]
    if len(plans) < MIN_CORPUS:
        return None
    X = np.stack([plan_features(p) for p in plans])  # (N, F)
    mu = X.mean(axis=0)
    sigma = X.std(axis=0)
    sigma[sigma < 1e-9] = 1.0
    Z = (X - mu) / sigma

    weights: dict = {}
    counts: dict = {}
    labels = sorted({lbl for p in plans for lbl in p.timings})
    for label in labels:
        idx = [i for i, p in enumerate(plans) if label in p.timings]
        counts[label] = len(idx)
        if len(idx) < MIN_LABEL_SAMPLES:
            continue
        Zi = Z[idx]
        y = np.log(
            np.maximum([plans[i].timings[label] for i in idx], 1e-12)
        )
        A = np.concatenate([np.ones((len(idx), 1)), Zi], axis=1)  # bias col
        lam = RIDGE_LAMBDA * np.eye(A.shape[1])
        lam[0, 0] = 0.0  # never shrink the bias
        weights[label] = np.linalg.solve(A.T @ A + lam, A.T @ y)
    if not weights:
        return None
    train_x = Z
    if len(train_x) > MAX_TRAIN_ROWS:  # deterministic subsample for OOD gate
        step = len(train_x) / MAX_TRAIN_ROWS
        train_x = train_x[(np.arange(MAX_TRAIN_ROWS) * step).astype(int)]
    _STATS["model_fits"] += 1
    return CostModel(
        device=device,
        kind=kind,
        mu=mu,
        sigma=sigma,
        weights=weights,
        label_counts=counts,
        train_x=train_x,
        n_train=len(plans),
    )


def model_key(kind: str, device: str) -> str:
    """Cache key for a persisted model — per device and model version, so
    a feature/format bump refits instead of replaying stale weights."""
    return f"cost-{kind}-{device}-v{COST_MODEL_VERSION}"


def load_or_fit(
    cache: Optional[PlanCache],
    device: str,
    kind: str,
    min_corpus: int = MIN_CORPUS,
) -> Optional[CostModel]:
    """The entry point consumers use: load the persisted model when it is
    still representative of the corpus, refit (and persist) when the
    corpus grew past ``REFIT_GROWTH`` x its training size or shrank, and
    return ``None`` when the corpus is too small to trust at all (the
    caller must then measure)."""
    from .cache import default_cache

    cache = cache if cache is not None else default_cache()
    plans = corpus(cache, device, kind)
    if len(plans) < min_corpus:
        return None
    stored = cache.load_model(model_key(kind, device))
    if stored is not None:
        try:
            model = CostModel.from_dict(stored)
        except (ValueError, KeyError):
            model = None
        if (
            model is not None
            and model.n_train <= len(plans) <= model.n_train * REFIT_GROWTH
        ):
            _STATS["model_loads"] += 1
            return model
    model = fit(plans, device, kind)
    if model is not None:
        cache.store_model(model_key(kind, device), model.to_dict())
    return model
