"""Variable Block Row (VBR) sparse matrix format.

The VBR format (Saad, SPARSKIT) partitions a matrix by row splits ``rpntr``
and column splits ``cpntr``; any block-row/block-column cell that contains at
least one non-zero is stored *densely* (column-major inside the block).  The
indirection arrays follow the paper (Fig. 3):

  val     values of stored blocks, column-major within each block
  indx    start offset of each stored block inside ``val`` (len = nblocks+1)
  bindx   block-column index of each stored block (row-major over block rows)
  rpntr   row-partition boundaries   (len = R+1)
  cpntr   column-partition boundaries(len = C+1)
  bpntrb  for each block row, start into ``bindx`` (-1 if the row is empty)
  bpntre  for each block row, end into ``bindx``

Everything except ``val`` is *structure*: it is known at staging time and is
partially evaluated away.  ``val`` is the only runtime input — the same staged
executable serves every matrix sharing the pattern.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterator, Sequence

import numpy as np

__all__ = [
    "VBR",
    "BlockTask",
    "from_dense",
    "synthesize",
    "synthesize_paper",
    "structure_hash",
]


@dataclasses.dataclass
class VBR:
    """A sparse matrix in Variable Block Row format."""

    shape: tuple[int, int]
    rpntr: np.ndarray  # (R+1,) int32
    cpntr: np.ndarray  # (C+1,) int32
    bindx: np.ndarray  # (nblocks,) int32
    bpntrb: np.ndarray  # (R,) int32, -1 for empty block rows
    bpntre: np.ndarray  # (R,) int32
    indx: np.ndarray  # (nblocks+1,) int64
    val: np.ndarray  # (nnz_stored,) — the ONLY runtime data

    # ------------------------------------------------------------------ #
    def __post_init__(self) -> None:
        self.rpntr = np.asarray(self.rpntr, dtype=np.int32)
        self.cpntr = np.asarray(self.cpntr, dtype=np.int32)
        self.bindx = np.asarray(self.bindx, dtype=np.int32)
        self.bpntrb = np.asarray(self.bpntrb, dtype=np.int32)
        self.bpntre = np.asarray(self.bpntre, dtype=np.int32)
        self.indx = np.asarray(self.indx, dtype=np.int64)

    @property
    def num_block_rows(self) -> int:
        return len(self.rpntr) - 1

    @property
    def num_block_cols(self) -> int:
        return len(self.cpntr) - 1

    @property
    def num_blocks(self) -> int:
        return len(self.bindx)

    @property
    def stored_nnz(self) -> int:
        return int(self.indx[-1])

    # ------------------------------------------------------------------ #
    def blocks(self) -> Iterator["BlockTask"]:
        """Stage-0 block iterator: yields one task per stored dense block.

        This is the paper's ``for block in vbr_matrix`` iterator: a pure
        Python traversal of the indirection arrays, fully evaluable at
        staging time.
        """
        count = 0
        for a in range(self.num_block_rows):
            if self.bpntrb[a] == -1:
                continue
            r0, r1 = int(self.rpntr[a]), int(self.rpntr[a + 1])
            for bi in range(int(self.bpntrb[a]), int(self.bpntre[a])):
                b = int(self.bindx[bi])
                c0, c1 = int(self.cpntr[b]), int(self.cpntr[b + 1])
                yield BlockTask(
                    block_row=a,
                    block_col=b,
                    row_start=r0,
                    row_end=r1,
                    col_start=c0,
                    col_end=c1,
                    val_offset=int(self.indx[count]),
                )
                count += 1

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.val.dtype)
        for t in self.blocks():
            h, w = t.row_end - t.row_start, t.col_end - t.col_start
            blk = self.val[t.val_offset : t.val_offset + h * w]
            # column-major inside the block, as in the paper
            out[t.row_start : t.row_end, t.col_start : t.col_end] = blk.reshape(
                w, h
            ).T
        return out

    def density(self) -> float:
        """Fraction of stored values that are non-zero (block fill ratio)."""
        if self.stored_nnz == 0:
            return 1.0
        return float(np.count_nonzero(self.val)) / float(self.stored_nnz)


@dataclasses.dataclass(frozen=True)
class BlockTask:
    """One stored dense block — the Stage-0 unit of work.

    All fields are Python ints known at staging time; the paper's Stage-1
    C code has them baked in as constants (Listing 2).  Here they are baked
    into the specialized jaxpr / Pallas block tables.
    """

    block_row: int
    block_col: int
    row_start: int
    row_end: int
    col_start: int
    col_end: int
    val_offset: int

    @property
    def height(self) -> int:
        return self.row_end - self.row_start

    @property
    def width(self) -> int:
        return self.col_end - self.col_start

    @property
    def size(self) -> int:
        return self.height * self.width


# ---------------------------------------------------------------------- #
# Construction
# ---------------------------------------------------------------------- #
def from_dense(
    dense: np.ndarray,
    rpntr: Sequence[int],
    cpntr: Sequence[int],
) -> VBR:
    """Build a VBR matrix from a dense array and given partitions.

    A block is stored iff it contains at least one non-zero (mostly-dense
    blocks keep their explicit zeros — that is the point of the format).
    """
    dense = np.asarray(dense)
    rpntr = np.asarray(rpntr, dtype=np.int32)
    cpntr = np.asarray(cpntr, dtype=np.int32)
    R, C = len(rpntr) - 1, len(cpntr) - 1
    bindx: list[int] = []
    bpntrb: list[int] = []
    bpntre: list[int] = []
    indx: list[int] = [0]
    vals: list[np.ndarray] = []
    for a in range(R):
        r0, r1 = rpntr[a], rpntr[a + 1]
        row_blocks = []
        for b in range(C):
            c0, c1 = cpntr[b], cpntr[b + 1]
            blk = dense[r0:r1, c0:c1]
            if np.any(blk != 0):
                row_blocks.append(b)
                vals.append(np.asarray(blk.T, order="C").reshape(-1))  # col-major
                indx.append(indx[-1] + blk.size)
        if row_blocks:
            bpntrb.append(len(bindx))
            bindx.extend(row_blocks)
            bpntre.append(len(bindx))
        else:
            bpntrb.append(-1)
            bpntre.append(-1)
    val = (
        np.concatenate(vals)
        if vals
        else np.zeros((0,), dtype=dense.dtype)
    )
    return VBR(
        shape=dense.shape,
        rpntr=rpntr,
        cpntr=cpntr,
        bindx=np.asarray(bindx, dtype=np.int32),
        bpntrb=np.asarray(bpntrb, dtype=np.int32),
        bpntre=np.asarray(bpntre, dtype=np.int32),
        indx=np.asarray(indx, dtype=np.int64),
        val=val,
    )


def _split_points(n: int, parts: int, uniform: bool, rng: np.random.Generator):
    """Partition ``[0, n)`` into ``parts`` pieces (uniform or random sizes)."""
    if parts >= n:
        return np.arange(n + 1, dtype=np.int32)
    if uniform:
        pts = np.linspace(0, n, parts + 1).round().astype(np.int32)
    else:
        cuts = np.sort(rng.choice(np.arange(1, n), size=parts - 1, replace=False))
        pts = np.concatenate([[0], cuts, [n]]).astype(np.int32)
    return pts


def synthesize(
    rows: int,
    cols: int,
    row_splits: int,
    col_splits: int,
    num_blocks: int,
    block_sparsity: float = 0.0,
    uniform: bool = True,
    seed: int = 0,
    dtype=np.float32,
) -> VBR:
    """The paper's matrix generator (Section V, 'Generating Matrices').

    Overlay a ``row_splits x col_splits`` grid on a ``rows x cols`` matrix,
    pick ``num_blocks`` random grid cells to be (mostly) dense blocks, and
    fill each chosen block with values where a ``block_sparsity`` fraction
    of entries are zeroed (the zeros SABLE tolerates).
    """
    rng = np.random.default_rng(seed)
    rpntr = _split_points(rows, row_splits, uniform, rng)
    cpntr = _split_points(cols, col_splits, uniform, rng)
    R, C = len(rpntr) - 1, len(cpntr) - 1
    total_cells = R * C
    num_blocks = min(num_blocks, total_cells)
    chosen = rng.choice(total_cells, size=num_blocks, replace=False)
    chosen = np.sort(chosen)

    bindx: list[int] = []
    bpntrb: list[int] = []
    bpntre: list[int] = []
    indx: list[int] = [0]
    vals: list[np.ndarray] = []
    by_row: dict[int, list[int]] = {}
    for cell in chosen:
        by_row.setdefault(int(cell) // C, []).append(int(cell) % C)
    for a in range(R):
        h = int(rpntr[a + 1] - rpntr[a])
        row_blocks = by_row.get(a)
        if not row_blocks:
            bpntrb.append(-1)
            bpntre.append(-1)
            continue
        bpntrb.append(len(bindx))
        for b in row_blocks:
            w = int(cpntr[b + 1] - cpntr[b])
            blk = rng.standard_normal(h * w).astype(dtype)
            if block_sparsity > 0:
                mask = rng.random(h * w) < block_sparsity
                blk[mask] = 0
                if np.all(blk == 0) and h * w > 0:
                    blk[0] = 1.0  # keep the block non-empty
            vals.append(blk)
            bindx.append(b)
            indx.append(indx[-1] + h * w)
        bpntre.append(len(bindx))
    val = np.concatenate(vals) if vals else np.zeros((0,), dtype=dtype)
    return VBR(
        shape=(rows, cols),
        rpntr=rpntr,
        cpntr=cpntr,
        bindx=np.asarray(bindx, dtype=np.int32),
        bpntrb=np.asarray(bpntrb, dtype=np.int32),
        bpntre=np.asarray(bpntre, dtype=np.int32),
        indx=np.asarray(indx, dtype=np.int64),
        val=val,
    )


def synthesize_paper(
    row_splits: int,
    col_splits: int,
    num_blocks: int,
    zeros_pct: int = 0,
    uniform: bool = True,
    seed: int = 0,
    rows: int = 10_000,
    cols: int = 10_000,
) -> VBR:
    """Matrices named ``<row_splits, col_splits, num_blocks, u|nu>`` in
    Tables I-IV of the paper (10k x 10k, block sparsity in percent)."""
    return synthesize(
        rows,
        cols,
        row_splits,
        col_splits,
        num_blocks,
        block_sparsity=zeros_pct / 100.0,
        uniform=uniform,
        seed=seed,
    )


def structure_hash(vbr: VBR) -> str:
    """Hash of the sparsity *pattern* only (never the values).

    This is the compile-once/run-many key: two matrices with equal hashes
    share the staged executable (paper Section III — specialization 'is
    focused on the sparse structure of the matrix, not ... the actual
    values').
    """
    h = hashlib.sha256()
    for arr in (vbr.rpntr, vbr.cpntr, vbr.bindx, vbr.bpntrb, vbr.bpntre, vbr.indx):
        h.update(np.ascontiguousarray(arr).tobytes())
    h.update(str(vbr.shape).encode())
    return h.hexdigest()[:16]
