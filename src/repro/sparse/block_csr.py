"""Fixed-block blocked-CSR-COO matrices: inspection-free dynamic sparsity.

``core.staging`` amortizes inspection over many calls with the SAME
structure; this module is the other regime — structures that change every
call (MoE routing emits a new topology per batch), where any host-side
inspection would land on the critical path.  Following MegaBlocks/STK
(SNIPPETS.md §1), a :class:`BlockMatrix` uses a *fixed* block size and a
hybrid blocked-CSR-COO encoding: per-block row indices (COO, sorted) for
the kernels' output schedule, column indices for the DMA gather, and CSR
row offsets for row lookup.  Everything — indices, offsets, validity — is
derivable **in-trace** from a routing mask with ``jnp.nonzero(size=...)``
and cumulative sums: no host sync, no staging, no plan cache.

Static shapes are preserved by padding to ``nnz_max`` block slots:
padded slots carry ``row == n_block_rows`` (an out-of-range sentinel that
sorts after every real row), ``col == 0`` and all-zero data, so every
consumer can either drop them (scatter ``mode='drop'``) or let them
accumulate zeros.  The invariant "invalid slots hold zero data" is
maintained by every constructor.

The compute family over this format lives in ``kernels.bsr_ops``
(``dsd`` / ``dds`` / ``sdd``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BlockMatrix", "mask_from_dense", "topology_from_mask"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockMatrix:
    """A (M, N) matrix stored as ``nnz_max`` fixed (bm, bn) blocks.

    Fields (all jnp arrays; shape/block are static aux data):
      data            (nnz_max, bm, bn)  block values, zero at invalid slots
      row_indices     (nnz_max,) int32   block-row per slot, SORTED ascending;
                                         invalid slots == n_block_rows
      column_indices  (nnz_max,) int32   block-col per slot; invalid == 0
      offsets         (n_block_rows+1,) int32  CSR offsets over valid blocks
    """

    shape: tuple  # (M, N) — static
    block: tuple  # (bm, bn) — static
    data: jnp.ndarray
    row_indices: jnp.ndarray
    column_indices: jnp.ndarray
    offsets: jnp.ndarray

    # -------------------------------------------------------------- #
    # pytree protocol: arrays are leaves, shape/block are aux data
    # -------------------------------------------------------------- #
    def tree_flatten(self):
        leaves = (self.data, self.row_indices, self.column_indices, self.offsets)
        return leaves, (self.shape, self.block)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        shape, block = aux
        return cls(shape, block, *leaves)

    # -------------------------------------------------------------- #
    @property
    def n_block_rows(self) -> int:
        return self.shape[0] // self.block[0]

    @property
    def n_block_cols(self) -> int:
        return self.shape[1] // self.block[1]

    @property
    def nnz_max(self) -> int:
        return self.data.shape[0]

    @property
    def valid(self) -> jnp.ndarray:
        """(nnz_max,) bool — which slots hold a real block."""
        return self.row_indices < self.n_block_rows

    @property
    def n_blocks(self) -> jnp.ndarray:
        """Traced count of valid blocks (== offsets[-1])."""
        return self.offsets[-1]

    def topology(self) -> "BlockMatrix":
        """Same structure, all-ones data — the ``sdd`` output template."""
        bm, bn = self.block
        ones = jnp.where(
            self.valid[:, None, None],
            jnp.ones((self.nnz_max, bm, bn), self.data.dtype),
            0.0,
        )
        return dataclasses.replace(self, data=ones)

    def with_data(self, data: jnp.ndarray) -> "BlockMatrix":
        """Replace block values (e.g. after an elementwise activation on
        ``.data``); invalid slots are re-zeroed to keep the invariant."""
        data = jnp.where(self.valid[:, None, None], data, 0.0)
        return dataclasses.replace(self, data=data)

    # -------------------------------------------------------------- #
    # constructors (all jit-traceable)
    # -------------------------------------------------------------- #
    @classmethod
    def from_mask(
        cls,
        mask: jnp.ndarray,  # (R, C) bool block-topology mask (traced OK)
        block: tuple,
        data: jnp.ndarray = None,  # (nnz_max, bm, bn) values for valid slots
        nnz_max: int = None,
        dtype=jnp.float32,
    ) -> "BlockMatrix":
        """Inspection-free construction from a block-topology mask.

        ``nnz_max`` bounds the number of True cells (static; defaults to
        the full grid).  Valid blocks come out row-major sorted because
        ``jnp.nonzero`` scans row-major; padding fills with the
        (n_block_rows, 0) sentinel.
        """
        R, C = mask.shape
        bm, bn = block
        nnz_max = int(R * C if nnz_max is None else nnz_max)
        nnz_max = max(nnz_max, 1)  # zero-size grids break pallas; pad 1 slot
        rows, cols = jnp.nonzero(
            mask, size=nnz_max, fill_value=(jnp.int32(R), jnp.int32(0))
        )
        rows = rows.astype(jnp.int32)
        cols = cols.astype(jnp.int32)
        offsets = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32),
             jnp.cumsum(mask.sum(axis=1)).astype(jnp.int32)]
        )
        if data is None:
            data = jnp.zeros((nnz_max, bm, bn), dtype)
        else:
            data = jnp.where((rows < R)[:, None, None], data, 0.0)
        return cls((R * bm, C * bn), (bm, bn), data, rows, cols, offsets)

    @classmethod
    def from_coo(
        cls,
        shape: tuple,
        block: tuple,
        data: jnp.ndarray,
        rows: jnp.ndarray,
        cols: jnp.ndarray,
    ) -> "BlockMatrix":
        """Assemble from already-sorted COO block coordinates (invalid
        slots marked with ``rows == n_block_rows``); recomputes offsets."""
        R = shape[0] // block[0]
        valid = rows < R
        counts = jnp.bincount(jnp.where(valid, rows, R), length=R + 1)[:R]
        offsets = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)]
        )
        data = jnp.where(valid[:, None, None], data, 0.0)
        return cls(
            tuple(shape), tuple(block), data,
            rows.astype(jnp.int32), cols.astype(jnp.int32), offsets,
        )

    @classmethod
    def from_dense(
        cls, x: jnp.ndarray, block: tuple, nnz_max: int = None
    ) -> "BlockMatrix":
        """Blockify a dense matrix, keeping blocks with any nonzero.
        With traced ``x`` this needs an explicit ``nnz_max`` bound to stay
        shape-static (defaults to the full grid)."""
        M, N = x.shape
        bm, bn = block
        assert M % bm == 0 and N % bn == 0, "dims must be block-aligned"
        blocks = x.reshape(M // bm, bm, N // bn, bn).transpose(0, 2, 1, 3)
        mask = jnp.any(blocks != 0, axis=(2, 3))
        sp = cls.from_mask(mask, block, nnz_max=nnz_max, dtype=x.dtype)
        rc = jnp.minimum(sp.row_indices, M // bm - 1)
        cc = jnp.minimum(sp.column_indices, N // bn - 1)
        return sp.with_data(blocks[rc, cc])

    @classmethod
    def from_pattern(cls, pattern, tiles: jnp.ndarray) -> "BlockMatrix":
        """From a static ``sparse.linear.BlockPattern`` (host-side tile
        coordinates, already row-major sorted) — zero padding slots, so
        ``tiles`` maps 1:1 onto ``data``."""
        rows = jnp.asarray(np.asarray(pattern.rows, dtype=np.int32))
        cols = jnp.asarray(np.asarray(pattern.cols, dtype=np.int32))
        return cls.from_coo(
            (pattern.d_in, pattern.d_out), (pattern.tm, pattern.tk),
            tiles, rows, cols,
        )

    # -------------------------------------------------------------- #
    def transpose(self) -> "BlockMatrix":
        """(N, M) view: swap block coordinates, restore row-sorted order
        (stable argsort keeps column order within a row)."""
        R = self.n_block_rows
        C = self.n_block_cols
        new_rows = jnp.where(self.valid, self.column_indices, C)
        new_cols = jnp.where(self.valid, self.row_indices, 0)
        order = jnp.argsort(new_rows, stable=True)
        return BlockMatrix.from_coo(
            (self.shape[1], self.shape[0]),
            (self.block[1], self.block[0]),
            jnp.transpose(self.data, (0, 2, 1))[order],
            new_rows[order],
            new_cols[order],
        )

    def to_dense(self) -> jnp.ndarray:
        """Scatter blocks back to (M, N); invalid slots drop."""
        R, C = self.n_block_rows, self.n_block_cols
        bm, bn = self.block
        grid = jnp.zeros((R, C, bm, bn), self.data.dtype)
        grid = grid.at[self.row_indices, self.column_indices].add(
            self.data, mode="drop"
        )
        return grid.transpose(0, 2, 1, 3).reshape(self.shape)

    def block_mask(self) -> jnp.ndarray:
        """(R, C) bool topology mask (the ``from_mask`` inverse)."""
        R, C = self.n_block_rows, self.n_block_cols
        m = jnp.zeros((R, C), bool)
        return m.at[self.row_indices, self.column_indices].set(
            True, mode="drop"
        )

    def density(self) -> jnp.ndarray:
        return self.n_blocks / max(self.n_block_rows * self.n_block_cols, 1)


def mask_from_dense(x: jnp.ndarray, block: tuple) -> jnp.ndarray:
    """(R, C) bool mask of blocks with any nonzero entry."""
    M, N = x.shape
    bm, bn = block
    blocks = x.reshape(M // bm, bm, N // bn, bn)
    return jnp.any(blocks != 0, axis=(1, 3))


def topology_from_mask(mask, block, nnz_max=None) -> BlockMatrix:
    """Shorthand for a data-less topology (the ``sdd`` third argument)."""
    return BlockMatrix.from_mask(mask, block, nnz_max=nnz_max)
