"""NN integration of SABLE block-sparse weights."""
from .block_csr import BlockMatrix, mask_from_dense, topology_from_mask
from .linear import (
    BlockPattern,
    choose_matmul_strategy,
    pack_dense,
    pattern_hash,
    prune_dense,
    random_pattern,
    sparse_matmul,
    sparse_matmul_auto,
    sparse_matmul_pallas,
    warm_matmul_plans,
)
