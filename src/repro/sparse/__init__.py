"""NN integration of SABLE block-sparse weights."""
from .linear import BlockPattern, pack_dense, random_pattern, sparse_matmul, prune_dense
