"""Block-sparse linear layers: SABLE staged patterns as NN weights.

This is the paper's motivating application (NN inference over pruned
weights with a fixed sparsity pattern — SpReg's setting).  A weight matrix
is stored as uniform (tm, tk) tiles plus a *static* pattern (tile
coordinates).  The pattern is structure — fixed at staging/trace time — so
XLA compiles a specialized program per pattern, exactly the SABLE contract;
the tile values are the trainable parameters.

Compute strategies mirror ``core.staging`` backends:
  * grouped einsum + scatter-add (XLA SPMD-shardable, default), or
  * the Pallas ``bsr_spmm`` kernel (TPU hot path).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BlockPattern",
    "random_pattern",
    "pack_dense",
    "prune_dense",
    "pattern_hash",
    "sparse_matmul",
    "sparse_matmul_pallas",
    "sparse_matmul_auto",
    "choose_matmul_strategy",
    "warm_matmul_plans",
]


@dataclasses.dataclass(frozen=True)
class BlockPattern:
    """Static block-sparsity pattern of a (d_in, d_out) weight matrix."""

    d_in: int
    d_out: int
    tm: int  # tile rows (input dim)
    tk: int  # tile cols (output dim)
    rows: tuple  # (nt,) tile-row coordinates
    cols: tuple  # (nt,) tile-col coordinates

    @property
    def n_tiles(self) -> int:
        return len(self.rows)

    @property
    def density(self) -> float:
        total = (self.d_in // self.tm) * (self.d_out // self.tk)
        return self.n_tiles / max(total, 1)

    def row_gather(self) -> np.ndarray:  # (nt, tm) input-dim indices
        r = np.asarray(self.rows)[:, None] * self.tm + np.arange(self.tm)[None, :]
        return r.astype(np.int32)

    def col_gather(self) -> np.ndarray:  # (nt, tk) output-dim indices
        c = np.asarray(self.cols)[:, None] * self.tk + np.arange(self.tk)[None, :]
        return c.astype(np.int32)

    def flops_fraction(self) -> float:
        return self.density


def random_pattern(
    d_in: int, d_out: int, tm: int, tk: int, density: float, seed: int = 0
) -> BlockPattern:
    """Random pattern with full row/col coverage (every input tile-row and
    output tile-col touched at least once, so no dead units)."""
    assert d_in % tm == 0 and d_out % tk == 0, "dims must be tile-aligned"
    R, C = d_in // tm, d_out // tk
    rng = np.random.default_rng(seed)
    n = max(int(round(density * R * C)), max(R, C))
    # coverage diagonal first
    diag = [(i % R, i % C) for i in range(max(R, C))]
    chosen = set(diag)
    all_cells = [(r, c) for r in range(R) for c in range(C)]
    rng.shuffle(all_cells)
    for cell in all_cells:
        if len(chosen) >= n:
            break
        chosen.add(cell)
    cells = sorted(chosen)
    rows = tuple(r for r, _ in cells)
    cols = tuple(c for _, c in cells)
    return BlockPattern(d_in, d_out, tm, tk, rows, cols)


def prune_dense(
    w: np.ndarray, tm: int, tk: int, density: float
) -> tuple[BlockPattern, np.ndarray]:
    """Magnitude-based block pruning of a dense matrix -> (pattern, tiles).

    Keeps the top ``density`` fraction of (tm, tk) blocks by Frobenius norm
    — how a real pruning pipeline would produce SABLE patterns.
    """
    d_in, d_out = w.shape
    assert d_in % tm == 0 and d_out % tk == 0
    R, C = d_in // tm, d_out // tk
    blocks = w.reshape(R, tm, C, tk).transpose(0, 2, 1, 3)  # (R, C, tm, tk)
    norms = np.sqrt((blocks**2).sum(axis=(2, 3)))
    n = max(int(round(density * R * C)), 1)
    thresh = np.partition(norms.reshape(-1), -n)[-n]
    keep = norms >= thresh
    rs, cs = np.nonzero(keep)
    order = np.lexsort((cs, rs))
    rs, cs = rs[order], cs[order]
    pattern = BlockPattern(d_in, d_out, tm, tk, tuple(rs.tolist()), tuple(cs.tolist()))
    tiles = blocks[rs, cs]  # (nt, tm, tk)
    return pattern, tiles


def pack_dense(w: jnp.ndarray, pattern: BlockPattern) -> jnp.ndarray:
    """Extract the pattern's tiles from a dense (d_in, d_out) matrix."""
    R = pattern.d_in // pattern.tm
    C = pattern.d_out // pattern.tk
    blocks = w.reshape(R, pattern.tm, C, pattern.tk).transpose(0, 2, 1, 3)
    return blocks[np.asarray(pattern.rows), np.asarray(pattern.cols)]


def _as_block_matrix(tiles: jnp.ndarray, pattern: BlockPattern):
    """View (pattern, tiles) as a fixed-block ``BlockMatrix`` — the static
    patterns of this module are just the slow-changing corner of the
    blocked-CSR-COO format (same row-major slot order, no padding)."""
    from .block_csr import BlockMatrix

    return BlockMatrix.from_pattern(pattern, tiles)


def sparse_matmul(x: jnp.ndarray, tiles: jnp.ndarray, pattern: BlockPattern):
    """y[..., d_out] = x[..., d_in] @ W_sparse — the ``dds`` member of the
    ``kernels.bsr_ops`` op family with the grouped-einsum backend (gather
    input tile-rows, batched tile matmul, scatter-add output cols).
    FLOPs = density * dense FLOPs; grads come from the family's
    ``custom_vjp`` (``d(dds)/d(sparse) = sdd``)."""
    from ..kernels.bsr_ops import dds

    lead = x.shape[:-1]
    y = dds(x.reshape(-1, pattern.d_in), _as_block_matrix(tiles, pattern),
            backend="grouped")
    return y.reshape(*lead, pattern.d_out)


def sparse_matmul_pallas(
    x: jnp.ndarray, tiles: jnp.ndarray, pattern: BlockPattern, interpret=None
):
    """TPU hot path: Pallas bsr_spmm over the pattern (x rows = tokens).

    The kernel computes W^T x^T layout-wise: we feed x^T as the dense
    operand with tile tables transposed so output columns become rows.
    """
    from ..kernels import ops as kops

    lead = x.shape[:-1]
    xt = x.reshape(-1, pattern.d_in).T  # (d_in, T)
    # kernel contracts tile @ x[tile_col_range] over rows => swap roles
    order = np.lexsort((np.asarray(pattern.rows), np.asarray(pattern.cols)))
    row_ids = np.asarray(pattern.cols)[order].astype(np.int32)  # output tiles
    col_ids = np.asarray(pattern.rows)[order].astype(np.int32)  # input tiles
    tiles_t = jnp.transpose(tiles[jnp.asarray(order)], (0, 2, 1))  # (nt, tk, tm)
    # coverage of all output tiles is guaranteed by random_pattern
    yt = kops.bsr_spmm(
        tiles_t,
        jnp.asarray(row_ids),
        jnp.asarray(col_ids),
        xt,
        m_pad=pattern.d_out,
        interpret=interpret,
    )
    return yt.T.reshape(*lead, pattern.d_out)


# ---------------------------------------------------------------------- #
# AD-safe Pallas dispatch: pallas_call has no transpose rule, so training
# through the kernel would raise.  Forward runs the kernel; backward is the
# (differentiable) gather/einsum formulation of the same contraction.
# ---------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pallas_matmul_ad(pattern: BlockPattern, x, tiles):
    return sparse_matmul_pallas(x, tiles, pattern)


def _pallas_matmul_fwd(pattern, x, tiles):
    return sparse_matmul_pallas(x, tiles, pattern), (x, tiles)


def _pallas_matmul_bwd(pattern, res, g):
    x, tiles = res
    rg = jnp.asarray(pattern.row_gather())  # (nt, tm)
    cg = jnp.asarray(pattern.col_gather())  # (nt, tk)
    gg = g[..., cg]  # (..., nt, tk)
    dx = (
        jnp.zeros_like(x)
        .at[..., rg]
        .add(jnp.einsum("...nk,nmk->...nm", gg, tiles))
    )
    dtiles = jnp.einsum("...nm,...nk->nmk", x[..., rg], gg)
    return dx, dtiles.astype(tiles.dtype)


_pallas_matmul_ad.defvjp(_pallas_matmul_fwd, _pallas_matmul_bwd)


# ---------------------------------------------------------------------- #
# Plan-driven strategy selection (shares core.cache with the autotuner)
# ---------------------------------------------------------------------- #
def _fixed_block_matmul(x: jnp.ndarray, tiles: jnp.ndarray,
                        pattern: BlockPattern):
    """Inspection-free strategy: route through the fixed-block op family
    (``kernels.bsr_ops.dds``, auto backend — pallas on TPU).  Used when
    the structure-change-rate arbiter decides the pattern churns too fast
    for staging/plan-caching to amortize."""
    from ..kernels.bsr_ops import dds

    lead = x.shape[:-1]
    y = dds(x.reshape(-1, pattern.d_in), _as_block_matrix(tiles, pattern))
    return y.reshape(*lead, pattern.d_out)


def _dia_split(pattern: BlockPattern):
    """Staging-time split of a pattern's tiles into the diagonal band
    (each output tile-col used by at most one band tile, so the diagonal
    half of the product is scatter-free) and the remainder."""
    rows = np.asarray(pattern.rows)
    cols = np.asarray(pattern.cols)
    R = max(pattern.d_in // pattern.tm, 1)
    C = max(pattern.d_out // pattern.tk, 1)
    band = np.abs((cols + 0.5) / C - (rows + 0.5) / R) <= max(1.0 / R, 1.0 / C)
    diag_idx: list[int] = []
    used_cols: set[int] = set()
    for i in np.nonzero(band)[0]:
        if int(cols[i]) not in used_cols:
            used_cols.add(int(cols[i]))
            diag_idx.append(int(i))
    off_idx = sorted(set(range(len(rows))) - set(diag_idx))
    return np.asarray(diag_idx, np.int64), np.asarray(off_idx, np.int64)


def _dia_hybrid_matmul(x: jnp.ndarray, tiles: jnp.ndarray,
                       pattern: BlockPattern):
    """DIA-hybrid strategy (kernels/dia_hybrid.py, NN-path counterpart):
    the diagonal-band tiles place their outputs with a precomputed gather
    (sentinel 0 = untouched col) instead of a scatter-add; only the
    remainder tiles go through the grouped scatter path."""
    lead = x.shape[:-1]
    diag_idx, off_idx = _dia_split(pattern)
    rows = np.asarray(pattern.rows)
    cols = np.asarray(pattern.cols)
    tm, tk = pattern.tm, pattern.tk
    xf = x.reshape(-1, pattern.d_in)
    y = jnp.zeros((xf.shape[0], pattern.d_out), dtype=x.dtype)
    if len(diag_idx):
        rg = rows[diag_idx][:, None] * tm + np.arange(tm)[None, :]
        part = jnp.einsum(
            "btm,tmk->btk", xf[:, jnp.asarray(rg)], tiles[jnp.asarray(diag_idx)]
        )
        place = np.zeros(pattern.d_out, np.int64)  # 0 = the sentinel zero
        for j, t in enumerate(diag_idx):
            c0 = int(cols[t]) * tk
            place[c0 : c0 + tk] = j * tk + np.arange(tk) + 1
        part1 = jnp.concatenate(
            [jnp.zeros((xf.shape[0], 1), part.dtype),
             part.reshape(xf.shape[0], -1)],
            axis=1,
        )
        y = part1[:, jnp.asarray(place)]
    if len(off_idx):
        sub = BlockPattern(
            pattern.d_in, pattern.d_out, tm, tk,
            tuple(int(r) for r in rows[off_idx]),
            tuple(int(c) for c in cols[off_idx]),
        )
        y = y + sparse_matmul(xf, tiles[jnp.asarray(off_idx)], sub)
    return y.reshape(*lead, pattern.d_out)


_MATMUL_IMPLS = {
    "grouped": sparse_matmul,
    "pallas": lambda x, tiles, pattern: _pallas_matmul_ad(pattern, x, tiles),
    "fixed_block": _fixed_block_matmul,
    "dia_hybrid": _dia_hybrid_matmul,
}
# (pattern hash, device) -> strategy name, resolved once per process
# (trace-safe).  The device is part of the key: the on-disk plan_key is
# device-specific, and a process whose default backend flips (cpu<->tpu
# test harnesses) must not replay the other backend's winner.
_STRATEGY_REGISTRY: dict[str, str] = {}

# bump when the hash *inputs* change so stale plan-cache entries keyed by
# the old hash miss instead of aliasing (v2: raw coordinate bytes — the
# v1 repr() of numpy coordinate arrays elided large patterns with "...",
# collapsing distinct >1k-tile patterns onto one key)
_PATTERN_HASH_VERSION = b"blockpattern-v2"


def pattern_hash(pattern: BlockPattern) -> str:
    """Structure hash of a BlockPattern (tile coords are the structure).

    Coordinates are canonicalized to int64 and hashed as raw bytes plus
    their shapes, so tuple- and ndarray-carrying patterns agree and large
    patterns never alias (numpy ``repr`` elision truncated them in v1).
    """
    import hashlib

    rows = np.asarray(pattern.rows, dtype=np.int64)
    cols = np.asarray(pattern.cols, dtype=np.int64)
    h = hashlib.sha256()
    h.update(_PATTERN_HASH_VERSION)
    h.update(
        np.asarray(
            [pattern.d_in, pattern.d_out, pattern.tm, pattern.tk,
             rows.size, cols.size],
            dtype=np.int64,
        ).tobytes()
    )
    h.update(rows.tobytes())
    h.update(cols.tobytes())
    return h.hexdigest()[:16]


def choose_matmul_strategy(
    pattern: BlockPattern,
    batch: int = 8,
    cache=None,
    allow_bench: bool = True,
    warmup: int = 1,
    iters: int = 3,
    shard=None,
    family: str = None,
    mode: str = "measure",
    cost_model=None,
    include_dia: bool = False,
) -> str:
    """Measured (or cached) choice between the grouped-einsum and Pallas
    sparse-matmul strategies for one pattern — the ``sparse.linear``
    counterpart of ``core.autotune``, persisted through the same plan cache
    keyed by ``pattern_hash``.

    ``shard=(shard_id, num_shards)`` keys the plan per shard of a device
    mesh (heterogeneous pools can then record different winners per
    device; see ``core.cache.plan_key``).

    On CPU the Pallas kernel only runs in interpret mode and can never win,
    so the candidate set collapses to ``grouped`` and no benchmark runs.

    With ``family=`` the structure-change-rate arbiter
    (``core.autotune.choose_format``) sees this pattern first: a family
    whose observed structure churns per call gets the inspection-free
    ``fixed_block`` strategy immediately — no benchmark, no registry or
    plan-cache write, since caching per-structure plans for a structure
    that never repeats only pollutes the cache.  Slow-changing families
    fall through to the staged (measured/cached) path below.

    ``mode="predict"`` consults the learned cost model over the ``linear``
    plan corpus (``core/cost_model.py``) before benchmarking: a confident
    prediction records a ``source="predicted"`` plan with ZERO
    micro-benchmarks (this is how ``warm_matmul_plans`` warms a thousand
    patterns in seconds); an uncertain one falls back to measurement.
    ``cost_model=`` pins a pre-loaded model so batch warmers fit once.

    ``include_dia=True`` opts into structure detection
    (``core.inspect.detect_pattern``): a pattern whose tiles sit densely on
    the diagonal band gains the ``dia_hybrid`` candidate (scatter-free
    diagonal placement, see ``_dia_hybrid_matmul``).  It is opt-in because
    it widens the candidate space — plans are therefore keyed with the
    ``rb`` plan-key segment (and an ``@rb`` registry suffix) so they never
    alias base-space plans, and because ``random_pattern`` seeds a coverage
    diagonal that would otherwise trip detection on patterns that are not
    meaningfully diagonal.  Note the pattern itself is never re-tiled:
    ``BlockPattern`` tiles are the parameter layout of a live model, so
    unlike VBR reblocking (``core.reblock``) only the *compute schedule*
    changes.  The ``family=`` churn check still runs first — churny
    patterns never pay for detection.
    """
    if mode not in ("measure", "predict"):
        raise ValueError(f"unknown strategy mode {mode!r}")
    from ..core import cache as cachelib
    from ..core.staging import StagingOptions

    phash = pattern_hash(pattern)
    if family is not None:
        from ..core.autotune import choose_format

        if choose_format(family, phash) == "fixed_block":
            return "fixed_block"
    device = jax.default_backend()
    reg_key = f"{phash}@{device}" if shard is None else (
        f"{phash}@{device}@s{shard[0]}of{shard[1]}"
    )
    if include_dia:
        reg_key += "@rb"  # extended candidate space: never alias base plans
    found = _STRATEGY_REGISTRY.get(reg_key)
    if found is not None:
        return found
    key = cachelib.plan_key(
        "linear", phash, device,
        shard_id=None if shard is None else shard[0],
        num_shards=None if shard is None else shard[1],
        reblock=include_dia,
    )
    store = cache if cache is not None else cachelib.default_cache()
    plan = store.load_plan(key)
    if plan is not None:
        _STRATEGY_REGISTRY[reg_key] = plan.options.backend
        return plan.options.backend

    candidates = ["grouped"] + (["pallas"] if device == "tpu" else [])
    struct_meta: dict = {}
    if include_dia:
        from ..core.inspect import detect_pattern

        info = detect_pattern(pattern)
        struct_meta = {
            "structure_class": info.structure_class,
            "bandwidth_frac": info.bandwidth_frac,
            "diag_occupancy": info.diag_occupancy,
        }
        if info.wants_dia:
            candidates.append("dia_hybrid")

    if mode == "predict" and len(candidates) > 1:
        from ..core import cost_model as cmlib

        model = (
            cost_model
            if cost_model is not None
            else cmlib.load_or_fit(store, device, "linear")
        )
        if model is not None:
            feats = cmlib.pattern_features(pattern)
            ok, _why = model.confident(feats, candidates)
            if ok:
                preds = model.predict(feats, candidates)
                best = min(preds, key=preds.get)
                plan = cachelib.TuningPlan(
                    kind="linear",
                    structure_hash=phash,
                    options=StagingOptions(
                        backend=best, tile=(pattern.tm, pattern.tk)
                    ),
                    device=device,
                    timings=preds,  # estimates, NOT measurements
                    meta={
                        "d_in": pattern.d_in,
                        "d_out": pattern.d_out,
                        "tm": pattern.tm,
                        "tk": pattern.tk,
                        "n_tiles": pattern.n_tiles,
                        "density": pattern.density,
                        **struct_meta,
                    },
                    source="predicted",
                )
                store.store_plan(key, plan)
                _STRATEGY_REGISTRY[reg_key] = best
                cmlib._STATS["plans_predicted"] += 1
                return best
        cmlib._STATS["predict_fallbacks"] += 1

    timings: dict[str, float] = {}
    if len(candidates) > 1 and allow_bench:
        from ..core.autotune import measure

        rng = np.random.default_rng(0)
        x = jnp.asarray(
            rng.standard_normal((batch, pattern.d_in)).astype(np.float32)
        )
        tiles = jnp.asarray(
            rng.standard_normal(
                (pattern.n_tiles, pattern.tm, pattern.tk)
            ).astype(np.float32)
        )
        for name in candidates:
            fn = jax.jit(lambda x, t, _f=_MATMUL_IMPLS[name]: _f(x, t, pattern))
            try:
                timings[name] = measure(fn, x, tiles, warmup=warmup, iters=iters)
            except Exception:
                continue
        best = min(timings, key=timings.get) if timings else "grouped"
        source = "measured" if timings else "heuristic"
    else:
        best = candidates[-1] if not allow_bench else candidates[0]
        source = "heuristic"

    plan = cachelib.TuningPlan(
        kind="linear",
        structure_hash=phash,
        options=StagingOptions(backend=best, tile=(pattern.tm, pattern.tk)),
        device=device,
        timings=timings,
        meta={
            "d_in": pattern.d_in,
            "d_out": pattern.d_out,
            "tm": pattern.tm,
            "tk": pattern.tk,
            "n_tiles": pattern.n_tiles,
            "density": pattern.density,
            **struct_meta,
            **({} if shard is None else
               {"shard_id": shard[0], "num_shards": shard[1]}),
        },
        source=source,
    )
    # a mid-trace heuristic fallback is provisional: keep it out of the
    # persistent cache so a later warm_matmul_plans() can still measure
    if source == "measured" or len(candidates) == 1:
        store.store_plan(key, plan)
        _STRATEGY_REGISTRY[reg_key] = best
    return best


def _seed_shard_strategy(pattern: BlockPattern, shard, strategy: str,
                         cache=None) -> str:
    """Record ``strategy`` under a per-shard plan key WITHOUT benchmarking
    (the device measured the full pattern once; a shard sees the same
    pattern, so the winner is inherited).  A plan already stored under the
    shard key — e.g. measured on that specific device of a heterogeneous
    pool — wins over the inherited default."""
    from ..core import cache as cachelib
    from ..core.staging import StagingOptions

    phash = pattern_hash(pattern)
    device = jax.default_backend()
    reg_key = f"{phash}@{device}@s{shard[0]}of{shard[1]}"
    found = _STRATEGY_REGISTRY.get(reg_key)
    if found is not None:
        return found
    key = cachelib.plan_key("linear", phash, device,
                            shard_id=shard[0], num_shards=shard[1])
    store = cache if cache is not None else cachelib.default_cache()
    plan = store.load_plan(key)
    if plan is None:
        plan = cachelib.TuningPlan(
            kind="linear",
            structure_hash=phash,
            options=StagingOptions(backend=strategy,
                                   tile=(pattern.tm, pattern.tk)),
            device=device,
            meta={"shard_id": shard[0], "num_shards": shard[1]},
            source="inherited",
        )
        store.store_plan(key, plan)
    _STRATEGY_REGISTRY[reg_key] = plan.options.backend
    return plan.options.backend


def warm_matmul_plans(patterns, batch: int = 8, cache=None, mesh=None,
                      shard_axis: str = "shards", mode: str = "measure",
                      include_dia: bool = False) -> dict:
    """Resolve strategies for many patterns ahead of tracing (server
    startup hook — e.g. ``ServeEngine``).  Returns {hash: strategy}.

    With ``mesh=`` the per-shard plan keys for the mesh's shard axis are
    resolved too (``<hash>@sIofN``): the measured winner is benchmarked
    ONCE per pattern and inherited by every shard (no per-shard
    re-benchmarks); a per-shard plan already on disk overrides it.  2-D
    (shards x model) staging meshes warm the same per-shard keys; a mesh
    with no shard axis at all (e.g. a pure ("data", "model") production
    mesh) warms the base plans only.

    ``mode="predict"`` loads/fits the cost model ONCE and resolves every
    cold pattern by prediction where the model is confident — this is the
    thousand-structure warm path: seconds of closed-form ranking instead
    of minutes of per-pattern micro-benchmarks, with per-pattern fallback
    to measurement for out-of-corpus or too-close calls."""
    out = {}
    shard_ids = []
    if mesh is not None:
        from ..core.sharded import resolve_shard_axis

        try:
            axis = resolve_shard_axis(mesh, shard_axis)
        except ValueError:
            axis = None  # no shard axis (e.g. TP-only mesh): base plans only
        if axis is not None:
            shard_ids = list(range(int(mesh.shape[axis])))
    model = None
    if mode == "predict":
        import jax as _jax

        from ..core import cache as cachelib
        from ..core import cost_model as cmlib

        store = cache if cache is not None else cachelib.default_cache()
        model = cmlib.load_or_fit(store, _jax.default_backend(), "linear")
    for p in patterns:
        base = choose_matmul_strategy(
            p, batch=batch, cache=cache, mode=mode, cost_model=model,
            include_dia=include_dia,
        )
        out[pattern_hash(p)] = base
        for i in shard_ids:
            shard = (i, len(shard_ids))
            out[f"{pattern_hash(p)}@s{i}of{len(shard_ids)}"] = (
                _seed_shard_strategy(p, shard, base, cache=cache)
            )
    return out


def sparse_matmul_auto(x: jnp.ndarray, tiles: jnp.ndarray,
                       pattern: BlockPattern, shard=None, mesh=None,
                       out_model: bool = False, family: str = None):
    """Plan-dispatched sparse matmul.  Inside a jit trace an unresolved
    pattern falls back to the device heuristic WITHOUT benchmarking (a
    micro-benchmark mid-trace would compile-thrash); call
    ``warm_matmul_plans`` first to get measured choices under jit.

    ``out_model=True`` marks the output's last dim as tensor-parallel:
    with an explicit ``mesh=`` (1-D or 2-D staging mesh) the constraint
    resolves against that mesh's model axis; without one it goes through
    ``distributed.ctx.constrain`` placeholders, so the same call composes
    with whatever ``activation_sharding`` context the launcher traced
    under (and is a no-op outside any context).
    """
    tracing = isinstance(x, jax.core.Tracer)
    strategy = choose_matmul_strategy(pattern, allow_bench=not tracing,
                                      shard=shard, family=family)
    y = _MATMUL_IMPLS[strategy](x, tiles, pattern)
    if out_model:
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from ..core.sharded import resolve_model_axis

            maxis = resolve_model_axis(mesh)
            if maxis is not None:
                y = jax.lax.with_sharding_constraint(
                    y,
                    NamedSharding(mesh, P(*([None] * (y.ndim - 1)), maxis)),
                )
        else:
            from ..distributed.ctx import MODEL, constrain

            y = constrain(y, *([None] * (y.ndim - 1) + [MODEL]))
    return y
