"""Structure-aware partitioner + sharded staged execution (single process).

Multi-device shard_map equivalence lives in tests/test_distributed.py
(subprocess with forced host devices); here: partition invariants, cache
round-trips, and host-loop numerical equivalence.
"""
import numpy as np
import pytest

from repro.core import vbr as vbrlib
from repro.core.cache import PlanCache
from repro.core.staging import StagingOptions, clear_cache, stage_spmm, stage_spmv
from repro.distributed.partition import (
    block_row_nnz,
    load_shard_plan,
    make_shard_plan,
    partition_nnz_balanced,
    save_shard_plan,
    shard_vbr,
)


@pytest.fixture(autouse=True)
def _fresh(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    clear_cache()
    yield
    clear_cache()


def _mk(seed=0, rows=240, cols=200, rs=24, cs=20, nb=90, sp=0.25):
    return vbrlib.synthesize(rows, cols, rs, cs, nb, sp, uniform=False, seed=seed)


# --------------------------------------------------------------------- #
# partition invariants
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", ["lpt", "contiguous"])
def test_partition_covers_every_row_once(strategy):
    """Shard row spans tile the matrix rows exactly (no gap, no overlap)."""
    v = _mk(seed=1)
    plan = make_shard_plan(v, 4, strategy)
    allrows = np.sort(np.concatenate([s.row_index for s in plan.shards]))
    np.testing.assert_array_equal(allrows, np.arange(v.shape[0]))
    # and the nnz accounting is exact
    assert int(plan.nnz_per_shard().sum()) == v.stored_nnz


@pytest.mark.parametrize("strategy", ["lpt", "contiguous"])
@pytest.mark.parametrize("num_shards", [2, 4, 8])
def test_partition_balance_bound(strategy, num_shards):
    """Worst shard holds <= 1.5x the mean nnz on random VBR structures."""
    for seed in range(4):
        v = _mk(seed=seed)
        plan = make_shard_plan(v, num_shards, strategy)
        assert plan.imbalance() <= 1.5, (
            f"seed={seed} {strategy} x{num_shards}: {plan.imbalance():.3f}"
        )
        np.testing.assert_array_equal(
            np.sort(plan.nnz_per_shard())[::-1].sum(), v.stored_nnz
        )


def test_partition_balances_nnz_not_row_count():
    """One giant block row + many tiny ones: row-count splitting would put
    the giant with others; nnz balancing splits the giant across shards."""
    dense = np.zeros((128, 64), np.float32)
    dense[:64] = 1.0  # block row 0: 64x64 dense (4096 nnz)
    for i in range(8):  # 8 tiny 8x8 blocks (512 nnz total)
        dense[64 + 8 * i : 72 + 8 * i, :8] = 1.0
    v = vbrlib.from_dense(dense, [0, 64] + list(range(72, 136, 8)), [0, 8, 64])
    sizes = block_row_nnz(v)
    assert sizes[0] == 64 * 64
    plan = make_shard_plan(v, 2, "lpt")
    # an indivisible block row would force 4096/2304 imbalance; row-span
    # splitting keeps the bound
    assert plan.imbalance() <= 1.5


def test_more_shards_than_rows():
    v = _mk(seed=2, rs=3, cs=3, nb=6)
    plan = make_shard_plan(v, 8)
    assert plan.num_shards == 8
    allrows = np.sort(np.concatenate([s.row_index for s in plan.shards]))
    np.testing.assert_array_equal(allrows, np.arange(v.shape[0]))


# --------------------------------------------------------------------- #
# shard-local structure correctness
# --------------------------------------------------------------------- #
def test_shard_vbr_reconstructs_rows():
    v = _mk(seed=3)
    dense = v.to_dense()
    plan = make_shard_plan(v, 4)
    seen = np.zeros(v.shape[0], bool)
    for s in plan.shards:
        sub = s.vbr.to_dense()
        np.testing.assert_array_equal(sub, dense[s.row_index])
        # runtime reslice of a FRESH global val matches the baked shard val
        np.testing.assert_array_equal(v.val[s.val_index], s.vbr.val)
        assert not seen[s.row_index].any()
        seen[s.row_index] = True


# --------------------------------------------------------------------- #
# cache round-trips
# --------------------------------------------------------------------- #
def test_shard_structures_roundtrip_cache(tmp_path):
    """Per-shard indirection arrays survive the persistent structure cache."""
    cache = PlanCache(str(tmp_path / "c"))
    v = _mk(seed=4)
    plan = make_shard_plan(v, 4)
    for s in plan.shards:
        h = vbrlib.structure_hash(s.vbr)
        cache.store_structure(s.vbr)
        back = cache.load_structure(h)
        assert back is not None
        for f in ("rpntr", "cpntr", "bindx", "bpntrb", "bpntre", "indx"):
            np.testing.assert_array_equal(getattr(back, f), getattr(s.vbr, f))
        assert back.shape == s.vbr.shape


def test_shard_plan_roundtrip_cache(tmp_path):
    cache = PlanCache(str(tmp_path / "c"))
    v = _mk(seed=5)
    plan = make_shard_plan(v, 4, "contiguous")
    save_shard_plan(plan, cache)
    back = load_shard_plan(v, 4, "contiguous", cache)
    assert back is not None
    assert back.shard_hashes() == plan.shard_hashes()
    for a, b in zip(plan.shards, back.shards):
        assert a.spans == b.spans
        np.testing.assert_array_equal(a.val_index, b.val_index)
    # miss on a different shard count / strategy
    assert load_shard_plan(v, 3, "contiguous", cache) is None
    assert load_shard_plan(v, 4, "lpt", cache) is None


# --------------------------------------------------------------------- #
# single- vs multi-shard numerical equivalence (host loop)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("num_shards", [1, 3, 8])
def test_sharded_spmv_matches_single(num_shards):
    import jax.numpy as jnp

    for seed in range(3):
        v = _mk(seed=10 + seed)
        rng = np.random.default_rng(seed)
        x = rng.standard_normal(v.shape[1]).astype(np.float32)
        ref = np.asarray(stage_spmv(v)(jnp.asarray(v.val), jnp.asarray(x)))
        got = np.asarray(
            stage_spmv(v, shards=num_shards)(jnp.asarray(v.val), jnp.asarray(x))
        )
        np.testing.assert_allclose(got, ref, atol=1e-6, rtol=1e-6)


def test_sharded_spmm_matches_single():
    import jax.numpy as jnp

    v = _mk(seed=20)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((v.shape[1], 8)).astype(np.float32)
    ref = np.asarray(stage_spmm(v, 8)(jnp.asarray(v.val), jnp.asarray(x)))
    got = np.asarray(
        stage_spmm(v, 8, shards=4)(jnp.asarray(v.val), jnp.asarray(x))
    )
    np.testing.assert_allclose(got, ref, atol=1e-6, rtol=1e-6)


def test_sharded_unrolled_backend():
    """Sharding composes with a non-default backend choice."""
    import jax.numpy as jnp

    v = _mk(seed=21, rs=6, cs=5, nb=12)
    rng = np.random.default_rng(1)
    x = rng.standard_normal(v.shape[1]).astype(np.float32)
    opts = StagingOptions(backend="unrolled")
    ref = np.asarray(stage_spmv(v, opts)(jnp.asarray(v.val), jnp.asarray(x)))
    got = np.asarray(
        stage_spmv(v, opts, shards=3)(jnp.asarray(v.val), jnp.asarray(x))
    )
    # different per-row accumulation order than the monolithic kernel
    np.testing.assert_allclose(got, ref, atol=5e-6, rtol=1e-5)


def test_linear_shard_plans_inherit_without_rebench(tmp_path):
    """warm_matmul_plans(mesh-less shard seeding): the base winner is
    measured once, shards inherit it, and a per-shard plan on disk wins."""
    from repro.core.cache import PlanCache, TuningPlan, plan_key
    from repro.core.staging import StagingOptions
    from repro.sparse import linear

    cache = PlanCache(str(tmp_path / "c"))
    pat = linear.random_pattern(32, 48, 8, 8, density=0.5)
    phash = linear.pattern_hash(pat)
    linear._STRATEGY_REGISTRY.clear()
    base = linear.choose_matmul_strategy(pat, cache=cache)
    # seed two shards from the base winner — no extra benchmarks, but a
    # pre-stored per-shard plan (heterogeneous pool) must override
    override_key = plan_key("linear", phash, "cpu", shard_id=1, num_shards=2)
    cache.store_plan(override_key, TuningPlan(
        kind="linear", structure_hash=phash,
        options=StagingOptions(backend="pallas"), device="cpu",
        source="measured"))
    s0 = linear._seed_shard_strategy(pat, (0, 2), base, cache=cache)
    s1 = linear._seed_shard_strategy(pat, (1, 2), base, cache=cache)
    assert s0 == base
    assert s1 == "pallas"  # disk plan wins over the inherited default
    # and the dispatcher consults the per-shard registry entry
    assert linear.choose_matmul_strategy(pat, cache=cache, shard=(0, 2)) == base
    linear._STRATEGY_REGISTRY.clear()


def test_sharded_autotune_persists_per_shard_plans(tmp_path, monkeypatch):
    import os

    import jax.numpy as jnp

    root = str(tmp_path / "plans")
    monkeypatch.setenv("REPRO_CACHE_DIR", root)
    v = _mk(seed=22)
    rng = np.random.default_rng(2)
    x = rng.standard_normal(v.shape[1]).astype(np.float32)
    kern = stage_spmv(v, StagingOptions(backend="autotune"), shards=3)
    ref = np.asarray(stage_spmv(v)(jnp.asarray(v.val), jnp.asarray(x)))
    got = np.asarray(kern(jnp.asarray(v.val), jnp.asarray(x)))
    np.testing.assert_allclose(got, ref, atol=1e-6, rtol=1e-6)
    names = os.listdir(os.path.join(root, "plans"))
    shard_keys = [n for n in names if "of3" in n]
    assert len(shard_keys) == 3  # one tuned plan per shard, parent-hash keyed
    assert any(n.startswith("shards-") for n in names)  # partition record
