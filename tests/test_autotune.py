"""Autotuner + persistent plan/structure cache (core.autotune, core.cache)."""
import dataclasses

import numpy as np
import pytest

from repro.core import vbr as vbrlib
from repro.core.autotune import (
    autotune,
    autotune_stage,
    autotune_stats,
    candidate_options,
    reset_autotune_stats,
    tune_num_workers,
)
from repro.core.cache import (
    PlanCache,
    TuningPlan,
    options_from_dict,
    options_to_dict,
    plan_key,
)
from repro.core.staging import (
    StagingOptions,
    clear_cache,
    partition_block_rows,
    stage_spmm,
    stage_spmv,
)
from repro.sparse.linear import (
    choose_matmul_strategy,
    pattern_hash,
    random_pattern,
    sparse_matmul_auto,
)


@pytest.fixture(autouse=True)
def _fresh_state():
    clear_cache()
    reset_autotune_stats()
    yield
    clear_cache()
    reset_autotune_stats()


def _mk(seed=0, rows=48, cols=40, rs=5, cs=4, nb=10, sp=0.3, uniform=False):
    return vbrlib.synthesize(rows, cols, rs, cs, nb, sp, uniform, seed)


# --------------------------------------------------------------------- #
# structure hash contract
# --------------------------------------------------------------------- #
def test_structure_hash_ignores_values():
    v1 = _mk(seed=3)
    v2 = vbrlib.VBR(
        shape=v1.shape,
        rpntr=v1.rpntr.copy(),
        cpntr=v1.cpntr.copy(),
        bindx=v1.bindx.copy(),
        bpntrb=v1.bpntrb.copy(),
        bpntre=v1.bpntre.copy(),
        indx=v1.indx.copy(),
        val=np.random.default_rng(9).standard_normal(v1.val.shape).astype(np.float32),
    )
    assert vbrlib.structure_hash(v1) == vbrlib.structure_hash(v2)


def test_structure_hash_stable_across_equivalent_vbrs():
    """from_dense of the same matrix + partition is bit-identical structure."""
    rng = np.random.default_rng(5)
    d = rng.standard_normal((24, 24)).astype(np.float32)
    d[d < 0.5] = 0
    splits = [0, 6, 13, 24]
    h1 = vbrlib.structure_hash(vbrlib.from_dense(d, splits, splits))
    h2 = vbrlib.structure_hash(vbrlib.from_dense(d.copy(), list(splits), splits))
    assert h1 == h2
    # a different partition of the same matrix is a different structure
    h3 = vbrlib.structure_hash(vbrlib.from_dense(d, [0, 12, 24], splits))
    assert h3 != h1


# --------------------------------------------------------------------- #
# StagingOptions / plan serialization
# --------------------------------------------------------------------- #
def test_options_roundtrip():
    for opts in (
        StagingOptions(),
        StagingOptions(backend="pallas", tile=(16, 128), spmm_bn=256,
                       interpret=True, prepack=True),
        StagingOptions(backend="grouped", density_threshold=0.5,
                       dtype=np.dtype("float32")),
    ):
        back = options_from_dict(options_to_dict(opts))
        assert back == opts, (opts, back)


def test_plan_cache_roundtrip(tmp_path):
    cache = PlanCache(str(tmp_path))
    plan = TuningPlan(
        kind="spmv",
        structure_hash="abcd1234abcd1234",
        options=StagingOptions(backend="bucketed", density_threshold=0.5),
        device="cpu",
        timings={"grouped": 1e-4, "bucketed": 5e-5},
        num_workers=4,
        meta={"shape": [48, 40]},
    )
    key = plan_key("spmv", plan.structure_hash, "cpu")
    cache.store_plan(key, plan)
    # reload through a FRESH cache object over the same directory
    loaded = PlanCache(str(tmp_path)).load_plan(key)
    assert loaded is not None
    assert loaded.options == plan.options
    assert loaded.timings == plan.timings
    assert loaded.num_workers == 4
    assert loaded.best_time == 5e-5


def test_plan_cache_corrupt_entry_is_miss(tmp_path):
    cache = PlanCache(str(tmp_path))
    key = plan_key("spmv", "feedbeeffeedbeef", "cpu")
    path = cache._plan_path(key)
    import os

    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("{not json")
    assert cache.load_plan(key) is None


def test_structure_cache_roundtrip(tmp_path):
    cache = PlanCache(str(tmp_path))
    v = _mk(seed=7)
    h = vbrlib.structure_hash(v)
    cache.store_structure(v)
    v2 = PlanCache(str(tmp_path)).load_structure(h, val=v.val)
    assert v2 is not None
    assert vbrlib.structure_hash(v2) == h
    np.testing.assert_array_equal(v2.to_dense(), v.to_dense())


# --------------------------------------------------------------------- #
# the tuner
# --------------------------------------------------------------------- #
def test_autotune_backend_correct_spmv(tmp_path):
    v = _mk()
    cache = PlanCache(str(tmp_path))
    kern = autotune_stage(v, "spmv", cache=cache, warmup=0, iters=1)
    x = np.random.default_rng(1).standard_normal(v.shape[1]).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(kern(v.val, x)), v.to_dense() @ x, rtol=1e-4, atol=1e-5
    )
    assert autotune_stats()["plans_tuned"] == 1
    assert autotune_stats()["benchmarks"] >= 2  # >1 candidate measured


def test_autotune_backend_correct_spmm(tmp_path):
    v = _mk(seed=2)
    cache = PlanCache(str(tmp_path))
    kern = autotune_stage(v, "spmm", n_cols=6, cache=cache, warmup=0, iters=1)
    x = np.random.default_rng(1).standard_normal((v.shape[1], 6)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(kern(v.val, x)), v.to_dense() @ x, rtol=1e-4, atol=1e-5
    )


def test_warm_cache_skips_benchmarks(tmp_path):
    v = _mk(seed=4)
    cache = PlanCache(str(tmp_path))
    plan_cold = autotune(v, "spmv", cache=cache, warmup=0, iters=1)
    assert plan_cold.source == "measured"
    assert autotune_stats()["benchmarks"] > 0

    # fresh process simulation: wipe in-memory state, keep the disk cache
    clear_cache()
    reset_autotune_stats()
    plan_warm = autotune(v, "spmv", cache=PlanCache(str(tmp_path)))
    stats = autotune_stats()
    assert stats["benchmarks"] == 0, "warm cache must not micro-benchmark"
    assert stats["cache_hits"] == 1 and stats["plans_tuned"] == 0
    assert plan_warm.options == plan_cold.options
    assert plan_warm.timings == pytest.approx(plan_cold.timings)


def test_stage_spmv_autotune_entry_point(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    from repro.core import cache as cachelib

    cachelib.set_default_cache(None)  # re-resolve from env
    v = _mk(seed=6)
    kern = stage_spmv(v, StagingOptions(backend="autotune"))
    x = np.random.default_rng(0).standard_normal(v.shape[1]).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(kern(v.val, x)), v.to_dense() @ x, rtol=1e-4, atol=1e-5
    )
    kern_m = stage_spmm(v, 4, StagingOptions(backend="autotune"))
    xm = np.random.default_rng(2).standard_normal((v.shape[1], 4)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(kern_m(v.val, xm)), v.to_dense() @ xm, rtol=1e-4, atol=1e-5
    )
    assert PlanCache(str(tmp_path)).stats()["plans"] == 2
    cachelib.set_default_cache(None)


def test_candidate_space_gating():
    v = _mk()
    labels = [lbl for lbl, _ in candidate_options(v, device="cpu")]
    assert "grouped" in labels and "bucketed" in labels
    assert not any(lbl.startswith("pallas") for lbl in labels)  # CPU-gated
    labels_tpu = [lbl for lbl, _ in candidate_options(v, device="tpu")]
    assert any(lbl.startswith("pallas") for lbl in labels_tpu)
    # unrolled drops out for huge block counts (HLO blowup guard)
    labels_big = [
        lbl for lbl, _ in candidate_options(v, device="cpu", max_unrolled_blocks=1)
    ]
    assert "unrolled" not in labels_big


# --------------------------------------------------------------------- #
# partition_block_rows / worker-split tuning
# --------------------------------------------------------------------- #
def test_partition_block_rows_load_balance():
    v = vbrlib.synthesize(200, 200, 20, 20, 90, 0.2, False, seed=11)
    sizes = np.zeros(v.num_block_rows, dtype=np.int64)
    for t in v.blocks():
        sizes[t.block_row] += t.size
    for w in (2, 4):
        bins = partition_block_rows(v, w)
        # every block row assigned exactly once
        flat = sorted(r for b in bins for r in b)
        assert flat == list(range(v.num_block_rows))
        loads = [int(sizes[list(b)].sum()) for b in bins]
        # LPT guarantee: makespan <= (4/3 - 1/3w) * OPT; OPT >= max(mean, max_row)
        opt_lb = max(float(np.max(sizes)), float(np.sum(sizes)) / w)
        assert max(loads) <= (4 / 3) * opt_lb + 1e-9


def test_tune_num_workers_sane():
    v = vbrlib.synthesize(200, 200, 20, 20, 90, 0.2, True, seed=1)
    w = tune_num_workers(v)
    assert 1 <= w <= v.num_block_rows
    # an empty matrix degenerates to one worker
    empty = vbrlib.from_dense(np.zeros((8, 8), np.float32), [0, 4, 8], [0, 4, 8])
    assert tune_num_workers(empty) == 1


def test_plan_records_num_workers(tmp_path):
    v = _mk(seed=8)
    plan = autotune(v, "spmv", cache=PlanCache(str(tmp_path)), warmup=0, iters=1)
    assert plan.num_workers == tune_num_workers(v)
    assert plan.meta["num_blocks"] == v.num_blocks


# --------------------------------------------------------------------- #
# sparse.linear plan API
# --------------------------------------------------------------------- #
def test_pattern_hash_and_strategy(tmp_path):
    p = random_pattern(32, 48, 8, 8, 0.4, seed=0)
    p_same = random_pattern(32, 48, 8, 8, 0.4, seed=0)
    p_other = random_pattern(32, 48, 8, 8, 0.4, seed=1)
    assert pattern_hash(p) == pattern_hash(p_same)
    assert pattern_hash(p) != pattern_hash(p_other)
    cache = PlanCache(str(tmp_path))
    strat = choose_matmul_strategy(p, cache=cache)
    assert strat in ("grouped", "pallas")
    # persisted: a fresh cache object over the same dir resolves identically
    from repro.sparse import linear as linlib

    linlib._STRATEGY_REGISTRY.clear()
    assert choose_matmul_strategy(p, cache=PlanCache(str(tmp_path))) == strat


def test_sparse_matmul_auto_matches_grouped(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    from repro.core import cache as cachelib
    from repro.sparse.linear import sparse_matmul

    cachelib.set_default_cache(None)
    p = random_pattern(32, 48, 8, 8, 0.5, seed=3)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 32)).astype(np.float32)
    tiles = rng.standard_normal((p.n_tiles, 8, 8)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(sparse_matmul_auto(x, tiles, p)),
        np.asarray(sparse_matmul(x, tiles, p)),
        rtol=1e-5,
    )
    cachelib.set_default_cache(None)


def test_autotune_rejects_bad_kind():
    v = _mk()
    with pytest.raises(ValueError):
        autotune(v, "spgemm")
    with pytest.raises(ValueError):
        autotune(v, "spmm")  # n_cols required


def test_autotune_carries_dtype_and_rejects_prepack(tmp_path):
    v = _mk(seed=14)
    cache = PlanCache(str(tmp_path))
    from repro.core import cache as cachelib

    cachelib.set_default_cache(cache)
    try:
        kern = stage_spmv(
            v, StagingOptions(backend="autotune", dtype=np.dtype("float64"))
        )
        assert kern.opts.dtype == np.dtype("float64")
        with pytest.raises(ValueError, match="prepack"):
            stage_spmv(v, StagingOptions(backend="autotune", prepack=True))
    finally:
        cachelib.set_default_cache(None)


def test_default_cache_explicit_wins_over_env(tmp_path, monkeypatch):
    from repro.core import cache as cachelib

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "env"))
    explicit = PlanCache(str(tmp_path / "explicit"))
    cachelib.set_default_cache(explicit)
    try:
        assert cachelib.default_cache() is explicit
    finally:
        cachelib.set_default_cache(None)
    # back to env-driven; and unsetting the env drops the stale root
    assert cachelib.default_cache().root == str(tmp_path / "env")
    monkeypatch.delenv("REPRO_CACHE_DIR")
    assert "env" not in cachelib.default_cache().root


def test_pallas_auto_dispatch_is_differentiable():
    """The 'pallas' strategy in sparse_matmul_auto must support jax.grad
    (training path); backward runs the grouped formulation."""
    import jax
    import jax.numpy as jnp

    from repro.sparse.linear import _MATMUL_IMPLS, sparse_matmul

    p = random_pattern(16, 24, 8, 8, 0.6, seed=5)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((3, 16)).astype(np.float32))
    tiles = jnp.asarray(
        rng.standard_normal((p.n_tiles, 8, 8)).astype(np.float32)
    )

    def loss(fn, x, t):
        return (fn(x, t, p) ** 2).sum()

    gx_ref, gt_ref = jax.grad(lambda x, t: loss(sparse_matmul, x, t), (0, 1))(
        x, tiles
    )
    gx, gt = jax.grad(
        lambda x, t: loss(_MATMUL_IMPLS["pallas"], x, t), (0, 1)
    )(x, tiles)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gt), np.asarray(gt_ref), rtol=1e-4)


def test_plan_options_are_concrete(tmp_path):
    plan = autotune(_mk(seed=12), "spmv", cache=PlanCache(str(tmp_path)),
                    warmup=0, iters=1)
    assert plan.options.backend not in ("auto", "autotune")
    # frozen dataclass: staging from the plan can't mutate it
    with pytest.raises(dataclasses.FrozenInstanceError):
        plan.options.backend = "gather"
