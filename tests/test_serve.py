"""Serving engine: generation consistency, batching, enc-dec."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serve.engine import ServeEngine


def test_greedy_generation_deterministic():
    cfg = get_config("llama3.2-3b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=24)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, cfg.vocab_size)
    out1, stats = eng.generate(prompts, max_new_tokens=8)
    out2, _ = eng.generate(prompts, max_new_tokens=8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (3, 16)
    assert stats["tokens_per_s"] > 0


def test_generation_matches_manual_decode():
    cfg = get_config("llama3.2-3b", reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    P, G = 8, 6
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, P), 0, cfg.vocab_size)
    eng = ServeEngine(cfg, params, max_len=P + G)
    out, _ = eng.generate(prompts, max_new_tokens=G)

    cache = init_cache(cfg, 2, P + G, dtype=jnp.float32)
    logits, cache = prefill(params, cfg, prompts, cache)
    nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None].astype(jnp.int32)
    toks = [nxt]
    for i in range(G - 1):
        lg, cache = decode_step(params, cfg, nxt, cache, jnp.int32(P + i))
        nxt = jnp.argmax(lg[:, 0].astype(jnp.float32), -1)[:, None].astype(jnp.int32)
        toks.append(nxt)
    manual = jnp.concatenate([prompts] + toks, axis=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(manual))


def test_encdec_generation():
    cfg = get_config("seamless-m4t-large-v2", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=16)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 4), 0, cfg.vocab_size)
    src = jax.random.normal(jax.random.PRNGKey(4), (2, 8, cfg.frontend_dim))
    out, _ = eng.generate(prompts, max_new_tokens=8, src_embeds=src)
    assert out.shape == (2, 12)
    assert bool((np.asarray(out) >= 0).all())


def test_temperature_sampling_runs():
    cfg = get_config("mamba2-1.3b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=20)
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 6), 0, cfg.vocab_size)
    out, _ = eng.generate(prompts, max_new_tokens=6, temperature=1.0,
                          rng=jax.random.PRNGKey(9))
    assert out.shape == (2, 12)
