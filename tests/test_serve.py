"""Serving engine: generation consistency, batching, enc-dec."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serve.engine import ServeEngine


def test_greedy_generation_deterministic():
    cfg = get_config("llama3.2-3b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=24)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, cfg.vocab_size)
    out1, stats = eng.generate(prompts, max_new_tokens=8)
    out2, _ = eng.generate(prompts, max_new_tokens=8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (3, 16)
    assert stats["tokens_per_s"] > 0


def test_generation_matches_manual_decode():
    cfg = get_config("llama3.2-3b", reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    P, G = 8, 6
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, P), 0, cfg.vocab_size)
    eng = ServeEngine(cfg, params, max_len=P + G)
    out, _ = eng.generate(prompts, max_new_tokens=G)

    cache = init_cache(cfg, 2, P + G, dtype=jnp.float32)
    logits, cache = prefill(params, cfg, prompts, cache)
    nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None].astype(jnp.int32)
    toks = [nxt]
    for i in range(G - 1):
        lg, cache = decode_step(params, cfg, nxt, cache, jnp.int32(P + i))
        nxt = jnp.argmax(lg[:, 0].astype(jnp.float32), -1)[:, None].astype(jnp.int32)
        toks.append(nxt)
    manual = jnp.concatenate([prompts] + toks, axis=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(manual))


def test_encdec_generation():
    cfg = get_config("seamless-m4t-large-v2", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=16)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 4), 0, cfg.vocab_size)
    src = jax.random.normal(jax.random.PRNGKey(4), (2, 8, cfg.frontend_dim))
    out, _ = eng.generate(prompts, max_new_tokens=8, src_embeds=src)
    assert out.shape == (2, 12)
    assert bool((np.asarray(out) >= 0).all())


def test_encdec_serve_falls_back_with_warning():
    """serve() on an enc-dec config can't use the paged scheduler; the
    fallback must be EXPLICIT: a warning (once per process) naming the
    reason, ``paged: False`` surfaced in warmup_stats, and results that
    match the generate() reference token-for-token."""
    import pytest

    import repro.serve.engine as engine_mod

    cfg = get_config("seamless-m4t-large-v2", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=16)
    prompt = np.array([1, 2, 3], np.int32)
    src = jax.random.normal(jax.random.PRNGKey(4), (8, cfg.frontend_dim))
    reqs = [{"prompt": prompt, "max_new_tokens": 5, "src_embeds": src,
             "rid": "e0"}]
    engine_mod._ENCDEC_FALLBACK_WARNED = False  # re-arm the once-guard
    with pytest.warns(UserWarning, match="paged"):
        results, sched = eng.serve(reqs)
    assert sched is None
    assert eng.warmup_stats["paged"] is False
    assert results["e0"]["state"] == "FINISHED"
    assert results["e0"]["prompt_len"] == 3
    assert results["e0"]["metrics"]["fallback"] == "generate"
    ref, _ = eng.generate(jnp.asarray(prompt)[None], 5, src_embeds=src[None])
    np.testing.assert_array_equal(results["e0"]["tokens"], np.asarray(ref[0]))
    # warn-once: a second serve() does not warn again
    import warnings

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        eng.serve(reqs)
    assert not caught


def test_temperature_sampling_runs():
    cfg = get_config("mamba2-1.3b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=20)
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 6), 0, cfg.vocab_size)
    out, _ = eng.generate(prompts, max_new_tokens=6, temperature=1.0,
                          rng=jax.random.PRNGKey(9))
    assert out.shape == (2, 12)


def test_serve_facade_matches_generate_on_state_space_model():
    """engine.serve() (continuous batching) on a pure-SSM model — every
    cache leaf is per-sequence state, no paged leaf — still matches the
    single-sequence path token-for-token."""
    cfg = get_config("mamba2-1.3b", reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=16)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (4, 7)]
    reqs = [{"prompt": p, "max_new_tokens": 5, "rid": f"f{i}"}
            for i, p in enumerate(prompts)]
    results, sched = eng.serve(reqs, page_size=4, max_batch=2)
    for i, p in enumerate(prompts):
        ref, _ = eng.generate(jnp.asarray(p)[None], 5)
        np.testing.assert_array_equal(
            results[f"f{i}"]["tokens"], np.asarray(ref)[0]
        )
    assert sched.stats["finished"] == 2


def test_warmup_skips_restaging_when_plan_cache_is_warm(tmp_path, monkeypatch):
    """A restarted process whose persistent plan cache already holds every
    plan for the active device must stage ZERO new plans at engine
    startup (the warm-cache admission acceptance criterion)."""
    from repro.configs import llama3_8b
    from repro.core import cache as cachelib

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    cachelib.set_default_cache(None)  # re-resolve the default from env
    try:
        cfg = llama3_8b.reduced_sable()
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng1 = ServeEngine(cfg, params, max_len=16)
        assert eng1.warmup_stats["warm_start"] is False
        assert eng1.warmup_stats["plans_staged"] >= 1
        # same process restarted: same params, same on-disk cache
        eng2 = ServeEngine(cfg, params, max_len=16)
        assert eng2.warmup_stats["warm_start"] is True
        assert eng2.warmup_stats["plans_staged"] == 0
        assert eng2.sparse_plans.keys() == eng1.sparse_plans.keys()
    finally:
        cachelib.set_default_cache(None)
