"""Learned cost model (core.cost_model) + autotune/linear predict modes.

Prediction-quality assertions run on *planted* corpora whose timings are
exact log-linear functions of the features — recoverable by the ridge
model to machine precision — so the >=80% top-1 agreement bar is a real
invariant, not a flaky micro-benchmark race.  Real measurements appear
only in fallback tests, where what is asserted is that measurement
HAPPENED.
"""
import itertools

import numpy as np
import pytest

import repro.core.autotune as autotune_mod
from repro.core import cost_model as cmlib
from repro.core import vbr as vbrlib
from repro.core.autotune import (
    autotune,
    autotune_stats,
    candidate_options,
    reset_autotune_stats,
    _structure_meta,
)
from repro.core.cache import PlanCache, TuningPlan, plan_key
from repro.core.staging import StagingOptions, clear_cache


@pytest.fixture(autouse=True)
def _fresh_state():
    clear_cache()
    reset_autotune_stats()
    cmlib.reset_cost_model_stats()
    yield
    clear_cache()
    reset_autotune_stats()
    cmlib.reset_cost_model_stats()


def _family(count, seed0=0):
    """Structures varying along block count — one in-distribution axis."""
    rng = np.random.default_rng(1)
    return [
        vbrlib.synthesize(
            400, 400, 10, 10, int(rng.integers(10, 60)), 0.3, False,
            seed=seed0 + s,
        )
        for s in range(count)
    ]


# planted per-label weights: (bias, coef on log_nnz, coef on log_blocks).
# Well separated, so predicted margins clear DEFAULT_MARGIN easily.
_WEIGHTS = {
    "grouped": (-12.0, 0.9, 0.0),
    "bucketed": (-10.0, 0.8, 0.35),
    "grouped+hybrid0.5": (-8.0, 0.85, 0.1),
}


def _planted_timings(feats, weights=_WEIGHTS):
    return {
        lbl: float(np.exp(b + c_nnz * feats[2] + c_nb * feats[3]))
        for lbl, (b, c_nnz, c_nb) in weights.items()
    }


def _seed_corpus(cache, vbrs, device="cpu"):
    for v in vbrs:
        meta = _structure_meta(v)
        feats = cmlib.meta_features("spmv", meta)
        h = vbrlib.structure_hash(v)
        cache.store_plan(
            plan_key("spmv", h, device),
            TuningPlan(
                kind="spmv",
                structure_hash=h,
                options=StagingOptions(backend="grouped"),
                device=device,
                timings=_planted_timings(feats),
                meta=meta,
                source="measured",
            ),
        )


# --------------------------------------------------------------------- #
# never-guess contract
# --------------------------------------------------------------------- #
def test_empty_corpus_predict_is_bitwise_measurement(tmp_path, monkeypatch):
    """With no corpus the predict mode IS the measure mode: same plan,
    bit for bit (deterministic fake measure makes timings comparable)."""
    v = _family(1)[0]

    def run(mode, root):
        calls = itertools.count()
        monkeypatch.setattr(
            autotune_mod, "measure",
            lambda fn, *a, **k: 0.001 * (next(calls) % 7 + 1),
        )
        return autotune(v, "spmv", mode=mode, cache=PlanCache(str(root)))

    p_measure = run("measure", tmp_path / "a")
    p_predict = run("predict", tmp_path / "b")
    assert p_predict.source == "measured"
    assert p_predict.to_dict() == p_measure.to_dict()
    assert cmlib.cost_model_stats()["predict_fallbacks"] == 1


def test_ood_structure_falls_back_to_measurement(tmp_path):
    cache = PlanCache(str(tmp_path))
    _seed_corpus(cache, _family(12))
    # far outside the corpus: 40x the rows, dense-ish
    big = vbrlib.synthesize(2000, 2000, 40, 40, 900, 0.05, True, seed=7)
    feats = cmlib.meta_features("spmv", _structure_meta(big))
    model = cmlib.load_or_fit(cache, "cpu", "spmv")
    ok, why = model.confident(
        feats, [lbl for lbl, _ in candidate_options(big, device="cpu")]
    )
    assert not ok and "out of corpus" in why

    plan = autotune(big, "spmv", mode="predict", cache=cache,
                    warmup=0, iters=1)
    assert plan.source == "measured"
    assert autotune_stats()["benchmarks"] > 0
    assert cmlib.cost_model_stats()["predict_fallbacks"] == 1


def test_unknown_candidate_label_refuses():
    vbrs = _family(12)
    plans = []
    for v in vbrs:
        meta = _structure_meta(v)
        feats = cmlib.meta_features("spmv", meta)
        t = _planted_timings(feats)
        t.pop("bucketed")  # corpus never saw this label
        plans.append(TuningPlan(
            kind="spmv", structure_hash=vbrlib.structure_hash(v),
            options=StagingOptions(backend="grouped"), device="cpu",
            timings=t, meta=meta, source="measured",
        ))
    model = cmlib.fit(plans, "cpu", "spmv")
    feats = cmlib.meta_features("spmv", _structure_meta(vbrs[0]))
    ok, why = model.confident(feats, ["grouped", "bucketed"])
    assert not ok and "bucketed" in why


def test_close_call_refuses():
    vbrs = _family(12)
    close = {"grouped": (-12.0, 0.9, 0.0), "bucketed": (-11.98, 0.9, 0.0)}
    plans = []
    for v in vbrs:
        meta = _structure_meta(v)
        feats = cmlib.meta_features("spmv", meta)
        plans.append(TuningPlan(
            kind="spmv", structure_hash=vbrlib.structure_hash(v),
            options=StagingOptions(backend="grouped"), device="cpu",
            timings=_planted_timings(feats, close), meta=meta,
            source="measured",
        ))
    model = cmlib.fit(plans, "cpu", "spmv")
    feats = cmlib.meta_features("spmv", _structure_meta(vbrs[0]))
    ok, why = model.confident(feats, ["grouped", "bucketed"])
    assert not ok and "margin" in why


# --------------------------------------------------------------------- #
# the confident path: zero benchmarks, measured-best agreement
# --------------------------------------------------------------------- #
def test_predict_stages_new_structure_with_zero_benchmarks(tmp_path):
    vbrs = _family(40)
    cache = PlanCache(str(tmp_path))
    _seed_corpus(cache, vbrs[:36])

    held = vbrs[37]
    plan = autotune(held, "spmv", mode="predict", cache=cache,
                    max_unrolled_blocks=0)
    assert plan.source == "predicted"
    assert autotune_stats()["benchmarks"] == 0
    assert autotune_stats()["plans_predicted"] == 1
    # the planted ground truth agrees with the prediction
    truth = _planted_timings(
        cmlib.meta_features("spmv", _structure_meta(held))
    )
    assert plan.options.backend == "grouped"
    assert min(truth, key=truth.get) == "grouped"
    # the predicted plan is cached and STAGEABLE without measurement
    from repro.core.autotune import autotune_stage

    kern = autotune_stage(held, "spmv", cache=cache, mode="predict",
                          max_unrolled_blocks=0)
    x = np.random.default_rng(0).standard_normal(held.shape[1]).astype(
        np.float32
    )
    np.testing.assert_allclose(
        np.asarray(kern(held.val, x)), held.to_dense() @ x,
        rtol=1e-4, atol=1e-5,
    )
    assert autotune_stats()["benchmarks"] == 0


def test_holdout_top1_agreement_at_least_80pct(tmp_path):
    """ISSUE 8 acceptance: >=80% top-1 backend agreement on held-out
    cached structures (leave-one-out over the planted corpus)."""
    cache = PlanCache(str(tmp_path))
    _seed_corpus(cache, _family(24))
    plans = cmlib.corpus(cache, "cpu", "spmv")
    assert len(plans) == 24
    agree = 0
    for i, held in enumerate(plans):
        model = cmlib.fit(plans[:i] + plans[i + 1:], "cpu", "spmv")
        preds = model.predict(cmlib.plan_features(held), held.timings)
        if min(preds, key=preds.get) == min(held.timings, key=held.timings.get):
            agree += 1
    assert agree / len(plans) >= 0.8


def test_predicted_plans_never_enter_the_corpus(tmp_path):
    vbrs = _family(40)
    cache = PlanCache(str(tmp_path))
    _seed_corpus(cache, vbrs[:36])
    autotune(vbrs[37], "spmv", mode="predict", cache=cache,
             max_unrolled_blocks=0)
    # the predicted plan is on disk...
    key = plan_key("spmv", vbrlib.structure_hash(vbrs[37]), "cpu")
    assert cache.load_plan(key).source == "predicted"
    # ...but the training corpus still only sees the measured 36
    assert len(cmlib.corpus(cache, "cpu", "spmv")) == 36


# --------------------------------------------------------------------- #
# persistence + refit policy
# --------------------------------------------------------------------- #
def test_model_persists_and_loads_without_refit(tmp_path):
    cache = PlanCache(str(tmp_path))
    _seed_corpus(cache, _family(12))
    m1 = cmlib.load_or_fit(cache, "cpu", "spmv")
    assert m1 is not None
    assert cmlib.cost_model_stats()["model_fits"] == 1
    assert cache.load_model(cmlib.model_key("spmv", "cpu")) is not None

    cmlib.reset_cost_model_stats()
    m2 = cmlib.load_or_fit(cache, "cpu", "spmv")
    assert cmlib.cost_model_stats() == {
        "model_fits": 0, "model_loads": 1,
        "plans_predicted": 0, "predict_fallbacks": 0,
    }
    assert m2.n_train == m1.n_train
    np.testing.assert_allclose(
        m2.weights["grouped"], m1.weights["grouped"]
    )


def test_model_refits_when_corpus_outgrows_it(tmp_path):
    cache = PlanCache(str(tmp_path))
    vbrs = _family(24)
    _seed_corpus(cache, vbrs[:12])
    m1 = cmlib.load_or_fit(cache, "cpu", "spmv")
    assert m1.n_train == 12
    # 12 -> 24 is past REFIT_GROWTH (1.5x): must refit, not replay
    _seed_corpus(cache, vbrs[12:])
    cmlib.reset_cost_model_stats()
    m2 = cmlib.load_or_fit(cache, "cpu", "spmv")
    assert m2.n_train == 24
    assert cmlib.cost_model_stats()["model_fits"] == 1


def test_corpus_too_small_returns_none(tmp_path):
    cache = PlanCache(str(tmp_path))
    _seed_corpus(cache, _family(cmlib.MIN_CORPUS - 1))
    assert cmlib.load_or_fit(cache, "cpu", "spmv") is None


def test_models_are_per_device(tmp_path):
    cache = PlanCache(str(tmp_path))
    _seed_corpus(cache, _family(12), device="tpu")
    assert cmlib.load_or_fit(cache, "cpu", "spmv") is None
    assert cmlib.load_or_fit(cache, "tpu", "spmv") is not None


def test_cache_stats_count_models(tmp_path):
    cache = PlanCache(str(tmp_path))
    _seed_corpus(cache, _family(12))
    cmlib.load_or_fit(cache, "cpu", "spmv")
    assert cache.stats()["models"] == 1
    cache.clear()
    assert cache.stats()["models"] == 0


# --------------------------------------------------------------------- #
# the linear (NN-path) consumer
# --------------------------------------------------------------------- #
def test_linear_predict_resolves_strategy_without_benchmarks(
    tmp_path, monkeypatch
):
    import jax

    from repro.sparse.linear import (
        choose_matmul_strategy,
        pattern_hash,
        random_pattern,
    )

    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    store = PlanCache(str(tmp_path))
    # corpus: densities sweeping the in-distribution axis, pallas planted
    # as the clear winner (log-linear in log_nnz)
    pats = [
        random_pattern(64, 64, 16, 16, 0.2 + 0.05 * i, seed=100 + i)
        for i in range(12)
    ]
    for p in pats[:10]:
        feats = cmlib.pattern_features(p)
        store.store_plan(
            plan_key("linear", pattern_hash(p), "tpu"),
            TuningPlan(
                kind="linear",
                structure_hash=pattern_hash(p),
                options=StagingOptions(backend="grouped", tile=(16, 16)),
                device="tpu",
                timings={
                    "grouped": float(np.exp(-10 + 0.9 * feats[2])),
                    "pallas": float(np.exp(-13 + 0.9 * feats[2])),
                },
                meta={"d_in": p.d_in, "d_out": p.d_out, "tm": p.tm,
                      "tk": p.tk, "n_tiles": p.n_tiles,
                      "density": p.density},
                source="measured",
            ),
        )

    strategy = choose_matmul_strategy(pats[11], cache=store, mode="predict")
    assert strategy == "pallas"
    assert autotune_stats()["benchmarks"] == 0
    assert cmlib.cost_model_stats()["plans_predicted"] == 1
    stored = store.load_plan(plan_key("linear", pattern_hash(pats[11]), "tpu"))
    assert stored.source == "predicted"


def test_warm_matmul_plans_predict_fits_model_once(tmp_path, monkeypatch):
    import jax

    from repro.sparse.linear import (
        pattern_hash,
        random_pattern,
        warm_matmul_plans,
    )

    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    store = PlanCache(str(tmp_path))
    pats = [
        random_pattern(64, 64, 16, 16, 0.2 + 0.05 * i, seed=200 + i)
        for i in range(14)
    ]
    for p in pats[:10]:
        feats = cmlib.pattern_features(p)
        store.store_plan(
            plan_key("linear", pattern_hash(p), "tpu"),
            TuningPlan(
                kind="linear", structure_hash=pattern_hash(p),
                options=StagingOptions(backend="grouped", tile=(16, 16)),
                device="tpu",
                timings={
                    "grouped": float(np.exp(-10 + 0.9 * feats[2])),
                    "pallas": float(np.exp(-13 + 0.9 * feats[2])),
                },
                meta={"d_in": p.d_in, "d_out": p.d_out, "tm": p.tm,
                      "tk": p.tk, "n_tiles": p.n_tiles,
                      "density": p.density},
                source="measured",
            ),
        )
    out = warm_matmul_plans(pats[10:], cache=store, mode="predict")
    assert len(out) == 4
    assert set(out.values()) == {"pallas"}
    st = cmlib.cost_model_stats()
    assert st["plans_predicted"] == 4
    assert st["model_fits"] + st["model_loads"] == 1  # fit once, shared
    assert autotune_stats()["benchmarks"] == 0


# --------------------------------------------------------------------- #
# serialization details
# --------------------------------------------------------------------- #
def test_feature_drift_invalidates_stored_model(tmp_path):
    cache = PlanCache(str(tmp_path))
    _seed_corpus(cache, _family(12))
    cmlib.load_or_fit(cache, "cpu", "spmv")
    doc = cache.load_model(cmlib.model_key("spmv", "cpu"))
    doc["feature_names"] = ["something_else"]
    cache.store_model(cmlib.model_key("spmv", "cpu"), doc)
    cmlib.reset_cost_model_stats()
    m = cmlib.load_or_fit(cache, "cpu", "spmv")  # refits instead of raising
    assert m is not None
    assert cmlib.cost_model_stats()["model_fits"] == 1


def test_old_plans_without_block_moments_featurize():
    meta = {"shape": [100, 100], "stored_nnz": 500, "num_blocks": 5}
    feats = cmlib.meta_features("spmv", meta)
    assert np.all(np.isfinite(feats))
    assert feats[4] == pytest.approx(np.log1p(100.0))  # mean = nnz/blocks


def test_invalid_mode_rejected():
    v = _family(1)[0]
    with pytest.raises(ValueError, match="mode"):
        autotune(v, "spmv", mode="guess")
