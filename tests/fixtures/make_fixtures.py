"""Regenerate the golden regression fixtures (tests/test_golden.py).

Run from the repo root after an INTENTIONAL format/schema change::

    PYTHONPATH=src python tests/fixtures/make_fixtures.py

Each fixture freezes (a) a small VBR structure with values, (b) the
dense-reference SpMV/SpMM outputs, (c) the structure hash, and (d) a
serialized TuningPlan — so a change to the hash function, the VBR
serialization, the partitioner, or the plan JSON schema fails the golden
suite loudly instead of silently invalidating every persisted cache.
"""
import json
import os
import sys

import numpy as np

sys.path.insert(
    0, os.path.join(os.path.dirname(__file__), "..", "..", "src")
)

from repro.core import vbr as vbrlib  # noqa: E402
from repro.core.cache import TuningPlan  # noqa: E402
from repro.core.staging import StagingOptions  # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))
N_COLS = 6  # SpMM RHS width frozen into the fixtures

_STRUCTURE_FIELDS = ("rpntr", "cpntr", "bindx", "bpntrb", "bpntre", "indx")


def banded() -> vbrlib.VBR:
    """Block-tridiagonal band: uniform 4-row/4-col splits, each block row
    stores its diagonal neighbourhood."""
    n = 48
    rng = np.random.default_rng(101)
    dense = np.zeros((n, n), np.float32)
    B = 4
    for a in range(n // B):
        for b in range(max(a - 1, 0), min(a + 2, n // B)):
            dense[a * B : (a + 1) * B, b * B : (b + 1) * B] = (
                rng.standard_normal((B, B))
            )
    splits = list(range(0, n + 1, B))
    return vbrlib.from_dense(dense, splits, splits)


def arrow() -> vbrlib.VBR:
    """Arrowhead: dense first block row + first block column + diagonal
    (non-uniform splits; the classic 'one giant hub' structure)."""
    n = 60
    rng = np.random.default_rng(202)
    dense = np.zeros((n, n), np.float32)
    splits = [0, 12, 20, 28, 40, 48, 60]
    R = len(splits) - 1
    for b in range(R):  # first block row
        dense[0 : splits[1], splits[b] : splits[b + 1]] = rng.standard_normal(
            (splits[1], splits[b + 1] - splits[b])
        )
    for a in range(R):  # first block col + diagonal
        dense[splits[a] : splits[a + 1], 0 : splits[1]] = rng.standard_normal(
            (splits[a + 1] - splits[a], splits[1])
        )
        dense[
            splits[a] : splits[a + 1], splits[a] : splits[a + 1]
        ] = rng.standard_normal(
            (splits[a + 1] - splits[a], splits[a + 1] - splits[a])
        )
    return vbrlib.from_dense(dense, splits, splits)


def random_block() -> vbrlib.VBR:
    """The paper's generator: non-uniform splits, 30 random blocks, 25%
    in-block zeros — with empty block rows."""
    return vbrlib.synthesize(
        120, 100, 10, 8, 30, block_sparsity=0.25, uniform=False, seed=42
    )


def misblocked_banded() -> vbrlib.VBR:
    """A narrow band stored under uniform 2-wide splits that ignore the
    band entirely — the canonical structure the reblocking DP repairs
    (tests/test_golden.py freezes the DP's proposal for it)."""
    n = 48
    rng = np.random.default_rng(303)
    dense = np.zeros((n, n), np.float32)
    for i in range(n):
        for j in range(max(0, i - 3), min(n, i + 4)):
            dense[i, j] = rng.standard_normal()
    splits = list(range(0, n + 1, 2))
    return vbrlib.from_dense(dense, splits, splits)


def write_reblock_fixture() -> None:
    """Freeze the reblocking DP's proposal on the misblocked band plus a
    plan carrying it — drift in the Ahrens–Boman cost function, the DP,
    the ``ReblockSpec`` schema, or the plan's ``reblock`` field fails the
    golden suite instead of silently orphaning cached reblocked plans."""
    from repro.core import reblock as rblib
    from repro.core.autotune import _structure_meta

    v = misblocked_banded()
    spec = rblib.propose_reblockings(v, device="cpu")[0]
    plan = TuningPlan(
        kind="spmv",
        structure_hash=vbrlib.structure_hash(v),
        options=StagingOptions(backend="grouped"),
        device="cpu",
        timings={"grouped": 2e-4, "reblock[dp]+grouped": 1e-4},
        meta={
            **_structure_meta(v),
            "reblock_fill_ratio": float(spec.fill_ratio),
        },
        source="measured",
        reblock=spec.to_dict(),
    )
    doc = {
        "structure_hash": vbrlib.structure_hash(v),
        "reblock": spec.to_dict(),
        "plan": plan.to_dict(),
    }
    with open(os.path.join(HERE, "reblock_plan.json"), "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(
        f"reblock: hash={doc['structure_hash']} strategy={spec.strategy} "
        f"cost={spec.cost:.0f} base={spec.base_cost:.0f} "
        f"fill={spec.fill_ratio:.3f}"
    )


def write_fixture(name: str, v: vbrlib.VBR) -> None:
    rng = np.random.default_rng(7)
    x = rng.standard_normal(v.shape[1]).astype(np.float32)
    X = rng.standard_normal((v.shape[1], N_COLS)).astype(np.float32)
    dense = v.to_dense()
    np.savez_compressed(
        os.path.join(HERE, f"{name}.npz"),
        shape=np.asarray(v.shape, np.int64),
        val=v.val,
        x=x,
        X=X,
        y_spmv=dense @ x,
        y_spmm=dense @ X,
        structure_hash=np.asarray(vbrlib.structure_hash(v)),
        **{f: getattr(v, f) for f in _STRUCTURE_FIELDS},
    )
    # frozen plan record: exercises the on-disk JSON schema round-trip
    plan = TuningPlan(
        kind="spmv",
        structure_hash=vbrlib.structure_hash(v),
        options=StagingOptions(backend="grouped"),
        device="cpu",
        timings={"grouped": 1e-4, "unrolled": 2e-4},
        num_workers=2,
        meta={
            "shape": [int(d) for d in v.shape],
            "num_blocks": int(v.num_blocks),
            "stored_nnz": int(v.stored_nnz),
        },
        source="measured",
    )
    with open(os.path.join(HERE, f"{name}_plan.json"), "w") as f:
        json.dump(plan.to_dict(), f, indent=1, sort_keys=True)
    print(
        f"{name}: shape={v.shape} blocks={v.num_blocks} "
        f"nnz={v.stored_nnz} hash={vbrlib.structure_hash(v)}"
    )


def write_serving_fixture() -> None:
    """Freeze the paged-cache layout and a 3-request continuous-batching
    transcript (tests/test_golden.py::test_golden_serving_*).

    Everything frozen here is integer-deterministic — admission order,
    evictions, page tables depend only on prompt/generation lengths and
    the FIFO allocator, never on token values — plus the decoded tokens
    themselves, which regression-pin the batched decode against the
    single-sequence path."""
    import itertools

    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.scheduler import ContinuousBatchingScheduler

    import dataclasses

    cfg = get_config("llama3.2-3b", reduced=True)
    cfg = dataclasses.replace(
        cfg, compute_dtype="float32", param_dtype="float32"
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    sched_args = {
        "max_len": 16, "page_size": 4, "max_batch": 3, "num_pages": 9
    }
    counter = itertools.count()
    sched = ContinuousBatchingScheduler(
        cfg, params, clock=lambda: float(next(counter)), **sched_args
    )
    rng = np.random.default_rng(77)
    requests = []
    for i, (P, G) in enumerate([(6, 8), (6, 8), (6, 8)]):
        prompt = rng.integers(0, cfg.vocab_size, size=(P,)).astype(np.int32)
        requests.append(
            {
                "rid": f"g{i}",
                "prompt": [int(t) for t in prompt],
                "max_new_tokens": G,
                "arrival": float(i),
            }
        )
        sched.submit(prompt, G, rid=f"g{i}", arrival=float(i))
    results = sched.run()
    kv = sched.kv
    doc = {
        "config": "llama3.2-3b",
        "scheduler": sched_args,
        "paged_cache": {
            "view_pages": kv.view_pages,
            "zero_page": kv.zero_page,
            "num_leaves": kv.num_leaves,
            "paged": list(kv.paged),
            "arena_shapes": [
                None if a is None else list(a.shape) for a in kv._arenas
            ],
        },
        "requests": requests,
        "transcript": sched.transcript,
        "stats": {
            k: sched.stats[k]
            for k in ("steps", "admissions", "evictions", "resumes", "finished")
        },
        "tokens": {
            rid: [int(t) for t in r["tokens"]] for rid, r in results.items()
        },
    }
    with open(os.path.join(HERE, "serving.json"), "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(
        f"serving: steps={sched.stats['steps']} "
        f"evictions={sched.stats['evictions']} resumes={sched.stats['resumes']}"
    )


if __name__ == "__main__":
    for name, build in [
        ("banded", banded),
        ("arrow", arrow),
        ("random_block", random_block),
    ]:
        write_fixture(name, build())
    write_reblock_fixture()
    write_serving_fixture()
