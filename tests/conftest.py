import os
import sys

# Tests see the normal single CPU device (the 512-device override is ONLY
# for the dry-run); keep determinism and quiet logs.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
