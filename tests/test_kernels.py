"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # keep deterministic cases running without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core import vbr as vbrlib
from repro.core.uniformize import uniformize
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _tiles(nb, tm, tk, n_rows, n_cols, seed, dtype=np.float32):
    """Random sorted tile tables with full output-row coverage."""
    rng = np.random.default_rng(seed)
    rows = np.sort(
        np.concatenate([np.arange(n_rows), rng.integers(0, n_rows, nb - n_rows)])
        if nb >= n_rows
        else np.sort(rng.permutation(n_rows)[:nb])
    ).astype(np.int32)
    cols = rng.integers(0, n_cols, nb).astype(np.int32)
    tiles = rng.standard_normal((nb, tm, tk)).astype(dtype)
    return tiles, rows, cols


@pytest.mark.parametrize("tm,tk", [(8, 8), (8, 16), (16, 8), (32, 32)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_spmm_shapes_dtypes(tm, tk, dtype):
    nb, n_rows, n_cols, N = 9, 4, 3, 24
    tiles, rows, cols = _tiles(nb, tm, tk, n_rows, n_cols, seed=0)
    tiles = jnp.asarray(tiles, dtype)
    x = jnp.asarray(
        np.random.default_rng(1).standard_normal((n_cols * tk, N)), dtype
    )
    y = kops.bsr_spmm(tiles, jnp.asarray(rows), jnp.asarray(cols), x,
                      m_pad=n_rows * tm, bn=8, interpret=True)
    ref = kref.bsr_spmm_ref(
        np.asarray(tiles, np.float32), rows, cols,
        np.asarray(x, np.float32), n_rows * tm,
    )
    tol = 6e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(ref), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("tm,tk", [(8, 8), (16, 32), (8, 128)])
def test_spmv_shapes(tm, tk):
    nb, n_rows, n_cols = 7, 3, 4
    tiles, rows, cols = _tiles(nb, tm, tk, n_rows, n_cols, seed=2)
    x = np.random.default_rng(3).standard_normal(n_cols * tk).astype(np.float32)
    y = kops.bsr_spmv(jnp.asarray(tiles), jnp.asarray(rows), jnp.asarray(cols),
                      jnp.asarray(x), m_pad=n_rows * tm, interpret=True)
    ref = kref.bsr_spmv_ref(tiles, rows, cols, x, n_rows * tm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    nb=st.integers(1, 12),
    tm=st.sampled_from([8, 16]),
    tk=st.sampled_from([8, 16]),
    n_rows=st.integers(1, 4),
    n_cols=st.integers(1, 4),
    n=st.integers(1, 17),
    seed=st.integers(0, 99),
)
def test_spmm_property(nb, tm, tk, n_rows, n_cols, n, seed):
    nb = max(nb, n_rows)  # coverage
    tiles, rows, cols = _tiles(nb, tm, tk, n_rows, n_cols, seed)
    x = np.random.default_rng(seed + 1).standard_normal(
        (n_cols * tk, n)
    ).astype(np.float32)
    y = kops.bsr_spmm(jnp.asarray(tiles), jnp.asarray(rows), jnp.asarray(cols),
                      jnp.asarray(x), m_pad=n_rows * tm, bn=8, interpret=True)
    ref = kref.bsr_spmm_ref(tiles, rows, cols, x, n_rows * tm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), tm=st.sampled_from([4, 8]), tk=st.sampled_from([4, 8]))
def test_uniformize_matches_vbr(seed, tm, tk):
    """pad-and-pack + kernel == densified VBR matmul (spmv)."""
    from repro.core.staging import StagedKernel, StagingOptions

    v = vbrlib.synthesize(37, 29, 4, 3, 6, 0.3, False, seed)
    x = np.random.default_rng(seed).standard_normal(v.shape[1]).astype(np.float32)
    k = StagedKernel(
        "spmv", v, StagingOptions(backend="pallas", tile=(tm, tk), interpret=True)
    )
    y = np.asarray(k(jnp.asarray(v.val), jnp.asarray(x)))
    np.testing.assert_allclose(y, v.to_dense() @ x, rtol=2e-4, atol=2e-4)
    assert 0.0 <= k.tiled.padded_fraction < 1.0


def test_uniformize_coverage_rows():
    """Empty block rows get zero coverage tiles (kernel init correctness)."""
    dense = np.zeros((32, 32), np.float32)
    dense[20:28, 4:12] = 1.0  # single block; rows 0..19, 28..31 empty
    v = vbrlib.from_dense(dense, [0, 8, 16, 24, 32], [0, 8, 16, 24, 32])
    descs = []
    from repro.core.staging import _inspect

    descs = _inspect(v, "spmv", None)
    t = uniformize(descs, 32, 32, v.rpntr, v.cpntr, 8, 8)
    assert set(t.row_ids.tolist()) == set(range(4))  # all row tiles covered
