"""End-to-end behaviour: the paper's headline claims, in-system.

1. Staged blocked evaluation beats the gather-based (zero-avoiding) CSR
   strategy on mostly-dense VBR matrices (the paper's core claim,
   qualitatively, on CPU wall-time with XLA as the 'stock compiler').
2. Compile-once/run-many: re-staging a same-pattern matrix is ~free.
3. The full pipeline quickstart: synthesize -> stage -> execute -> verify.
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import vbr as vbrlib
from repro.core.staging import StagingOptions, clear_cache, stage_spmv


def _csr_spmv_baseline(v):
    """The 'avoid every zero' strategy class (PSC/SpReg's family):
    gather-based unstructured CSR in JAX."""
    d = v.to_dense()
    rows, cols = np.nonzero(d)
    vals = jnp.asarray(d[rows, cols])
    rows_j = jnp.asarray(rows)
    cols_j = jnp.asarray(cols)
    m = d.shape[0]

    @jax.jit
    def f(vals, x):
        return jnp.zeros(m, x.dtype).at[rows_j].add(vals * x[cols_j])

    return f, vals


def test_staged_beats_csr_on_mostly_dense():
    v = vbrlib.synthesize(2000, 2000, 20, 20, 80, block_sparsity=0.2, seed=0)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(2000), jnp.float32)
    k = stage_spmv(v, StagingOptions(backend="grouped"))
    val = jnp.asarray(v.val)
    ref = v.to_dense() @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(k(val, x)), ref, rtol=2e-3, atol=2e-3)

    csr, cvals = _csr_spmv_baseline(v)
    np.testing.assert_allclose(np.asarray(csr(cvals, x)), ref, rtol=2e-3,
                               atol=2e-3)

    def bench(f, *args, n=20):
        f(*args).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(n):
            f(*args).block_until_ready()
        return (time.perf_counter() - t0) / n

    t_staged = bench(k, val, x)
    t_csr = bench(csr, cvals, x)
    # SABLE claim: regular blocked loops beat gather-based zero avoidance
    assert t_staged < t_csr, (t_staged, t_csr)


def test_compile_once_run_many():
    clear_cache()
    v = vbrlib.synthesize(500, 500, 10, 10, 30, seed=1)
    t0 = time.perf_counter()
    k1 = stage_spmv(v, StagingOptions(backend="grouped"))
    x = jnp.ones(500, jnp.float32)
    k1(jnp.asarray(v.val), x).block_until_ready()
    first = time.perf_counter() - t0

    v2 = vbrlib.VBR(**{**v.__dict__})
    v2.val = v.val * 5.0
    t0 = time.perf_counter()
    k2 = stage_spmv(v2, StagingOptions(backend="grouped"))
    k2(jnp.asarray(v2.val), x).block_until_ready()
    second = time.perf_counter() - t0
    assert k1 is k2
    assert second < first / 2  # no re-staging, no re-compile


def test_quickstart_pipeline():
    v = vbrlib.synthesize(300, 400, 6, 8, 20, block_sparsity=0.3, seed=2)
    X = np.random.default_rng(2).standard_normal((400, 16)).astype(np.float32)
    from repro.core.staging import stage_spmm

    k = stage_spmm(v, 16, StagingOptions(backend="grouped"))
    y = np.asarray(k(jnp.asarray(v.val), jnp.asarray(X)))
    np.testing.assert_allclose(y, v.to_dense() @ X, rtol=2e-3, atol=2e-3)
