"""Fallback decorators when ``hypothesis`` is not installed.

Property-based tests collect as skipped; deterministic tests in the same
module keep running.  Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:  # pragma: no cover - exercised without hypothesis
        from _hypothesis_stub import given, settings, st
"""
import pytest


class _AnyStrategy:
    """Stands in for ``hypothesis.strategies``: every attribute is a
    callable returning None (the stub ``given`` never draws from it)."""

    def __getattr__(self, name):
        def _strategy(*args, **kwargs):
            return None

        return _strategy


st = _AnyStrategy()


def settings(*args, **kwargs):
    def deco(fn):
        return fn

    return deco


def given(*_args, **_kwargs):
    def deco(fn):
        @pytest.mark.skip(reason="hypothesis not installed")
        def skipped():
            pass

        skipped.__name__ = fn.__name__
        skipped.__doc__ = fn.__doc__
        return skipped

    return deco
