"""Deterministic fallback when ``hypothesis`` is not installed.

Instead of skipping, property-based tests run against FIXED-SEED samples
drawn from a miniature strategy implementation: ``@given`` replays the
test body over ``max_examples`` deterministic draws (seeded from the test
name, stable across runs and machines), so tier-1 keeps real property
coverage without the hypothesis dependency.  With hypothesis installed
the real library is used and this module is never imported.  Usage in a
test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:  # pragma: no cover - exercised without hypothesis
        from _hypothesis_stub import given, settings, st
"""
import functools
import inspect
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    """A deterministic value source: ``draw(rng)`` -> one example."""

    def __init__(self, draw, label=""):
        self._draw = draw
        self._label = label

    def draw(self, rng):
        return self._draw(rng)

    def __repr__(self):
        return f"_Strategy({self._label})"


class _Strategies:
    """Stands in for ``hypothesis.strategies`` — the subset the test suite
    uses, drawing deterministically from a seeded Generator.  Unknown
    strategies raise at collection time so a new test can't silently lose
    its property coverage."""

    @staticmethod
    def integers(min_value=0, max_value=(1 << 31) - 1):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            f"integers({min_value}, {max_value})",
        )

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            f"floats({min_value}, {max_value})",
        )

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans()")

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(
            lambda rng: seq[int(rng.integers(len(seq)))],
            f"sampled_from({seq!r})",
        )

    @staticmethod
    def just(value):
        return _Strategy(lambda rng: value, f"just({value!r})")

    def __getattr__(self, name):
        raise NotImplementedError(
            f"_hypothesis_stub has no strategy {name!r}; install hypothesis "
            "or extend tests/_hypothesis_stub.py"
        )


st = _Strategies()


def settings(*args, max_examples=None, **kwargs):
    """Record ``max_examples`` for the stub ``given`` loop; every other
    hypothesis setting (deadline, suppress_health_check, ...) is
    meaningless here and ignored."""

    def deco(fn):
        if max_examples is not None:
            fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*args, **strategies):
    """Replay the test over deterministic fixed-seed draws.

    Only keyword strategies are supported (the repo convention); each
    example ``i`` draws every kwarg from a Generator seeded by
    ``crc32(<test name>:<i>)`` — stable across runs, machines, and test
    orderings.  A failing example re-raises with the drawn kwargs in the
    message so it can be reproduced as a plain call.
    """
    if args:
        raise TypeError(
            "_hypothesis_stub.given supports keyword strategies only, e.g. "
            "@given(seed=st.integers(0, 100))"
        )

    def deco(fn):
        @functools.wraps(fn)
        def runner(*fargs, **fkwargs):
            n = getattr(
                runner,
                "_stub_max_examples",
                getattr(fn, "_stub_max_examples", DEFAULT_MAX_EXAMPLES),
            )
            name = f"{fn.__module__}.{fn.__qualname__}"
            for i in range(n):
                rng = np.random.default_rng(
                    zlib.crc32(f"{name}:{i}".encode()) & 0x7FFFFFFF
                )
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*fargs, **dict(fkwargs, **drawn))
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example {i + 1}/{n} (fixed-seed stub): "
                        f"{fn.__name__}(**{drawn!r})"
                    ) from e

        # pytest resolves fixtures from the (wrapped) signature; the drawn
        # params are NOT fixtures, so expose only the non-strategy ones
        params = [
            p
            for name, p in inspect.signature(fn).parameters.items()
            if name not in strategies
        ]
        runner.__signature__ = inspect.Signature(params)
        return runner

    return deco
