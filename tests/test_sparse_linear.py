"""SABLE block-sparse NN weights: patterns, matmuls, pruning."""
import numpy as np
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # keep deterministic cases running without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.sparse.linear import (
    pack_dense,
    prune_dense,
    random_pattern,
    sparse_matmul,
    sparse_matmul_pallas,
)


def _dense_of(pattern, tiles):
    w = np.zeros((pattern.d_in, pattern.d_out), np.float32)
    for t, (r, c) in enumerate(zip(pattern.rows, pattern.cols)):
        w[r * pattern.tm : (r + 1) * pattern.tm,
          c * pattern.tk : (c + 1) * pattern.tk] = tiles[t]
    return w


@settings(max_examples=20, deadline=None)
@given(
    ri=st.sampled_from([2, 3, 4]),
    ci=st.sampled_from([2, 3, 5]),
    tm=st.sampled_from([4, 8]),
    tk=st.sampled_from([4, 8]),
    density=st.floats(0.2, 1.0),
    seed=st.integers(0, 100),
)
def test_sparse_matmul_matches_dense(ri, ci, tm, tk, density, seed):
    d_in, d_out = ri * tm, ci * tk
    pat = random_pattern(d_in, d_out, tm, tk, density, seed)
    rng = np.random.default_rng(seed)
    tiles = rng.standard_normal((pat.n_tiles, tm, tk)).astype(np.float32)
    x = rng.standard_normal((3, 5, d_in)).astype(np.float32)
    y = sparse_matmul(jnp.asarray(x), jnp.asarray(tiles), pat)
    ref = x @ _dense_of(pat, tiles)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


def test_pattern_coverage():
    pat = random_pattern(64, 128, 8, 16, density=0.2, seed=0)
    assert set(pat.rows) == set(range(8))  # every input tile-row used
    assert set(pat.cols) == set(range(8))  # every output tile-col used
    assert 0.15 <= pat.density <= 0.35


def test_prune_dense_keeps_top_blocks():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((32, 32)).astype(np.float32) * 0.01
    w[0:8, 0:8] = 10.0  # dominant block must survive pruning
    pat, tiles = prune_dense(w, 8, 8, density=0.25)
    assert (0, 0) in set(zip(pat.rows, pat.cols))
    assert pat.n_tiles == 4
    y = sparse_matmul(jnp.eye(32), jnp.asarray(tiles), pat)
    kept = _dense_of(pat, tiles)
    np.testing.assert_allclose(np.asarray(y), kept, rtol=1e-5)


def test_pack_dense_roundtrip():
    pat = random_pattern(32, 48, 8, 8, 0.5, seed=1)
    rng = np.random.default_rng(1)
    tiles = rng.standard_normal((pat.n_tiles, 8, 8)).astype(np.float32)
    w = _dense_of(pat, tiles)
    np.testing.assert_allclose(np.asarray(pack_dense(jnp.asarray(w), pat)), tiles)


def test_pallas_path_matches_grouped():
    pat = random_pattern(32, 64, 8, 16, 0.5, seed=2)
    rng = np.random.default_rng(2)
    tiles = rng.standard_normal((pat.n_tiles, 8, 16)).astype(np.float32)
    x = rng.standard_normal((6, 32)).astype(np.float32)
    y1 = sparse_matmul(jnp.asarray(x), jnp.asarray(tiles), pat)
    y2 = sparse_matmul_pallas(jnp.asarray(x), jnp.asarray(tiles), pat,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
