"""SABLE block-sparse NN weights: patterns, matmuls, pruning."""
import numpy as np
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # keep deterministic cases running without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.sparse.linear import (
    BlockPattern,
    choose_matmul_strategy,
    pack_dense,
    pattern_hash,
    prune_dense,
    random_pattern,
    sparse_matmul,
    sparse_matmul_pallas,
)


def _dense_of(pattern, tiles):
    w = np.zeros((pattern.d_in, pattern.d_out), np.float32)
    for t, (r, c) in enumerate(zip(pattern.rows, pattern.cols)):
        w[r * pattern.tm : (r + 1) * pattern.tm,
          c * pattern.tk : (c + 1) * pattern.tk] = tiles[t]
    return w


@settings(max_examples=20, deadline=None)
@given(
    ri=st.sampled_from([2, 3, 4]),
    ci=st.sampled_from([2, 3, 5]),
    tm=st.sampled_from([4, 8]),
    tk=st.sampled_from([4, 8]),
    density=st.floats(0.2, 1.0),
    seed=st.integers(0, 100),
)
def test_sparse_matmul_matches_dense(ri, ci, tm, tk, density, seed):
    d_in, d_out = ri * tm, ci * tk
    pat = random_pattern(d_in, d_out, tm, tk, density, seed)
    rng = np.random.default_rng(seed)
    tiles = rng.standard_normal((pat.n_tiles, tm, tk)).astype(np.float32)
    x = rng.standard_normal((3, 5, d_in)).astype(np.float32)
    y = sparse_matmul(jnp.asarray(x), jnp.asarray(tiles), pat)
    ref = x @ _dense_of(pat, tiles)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


def test_pattern_coverage():
    pat = random_pattern(64, 128, 8, 16, density=0.2, seed=0)
    assert set(pat.rows) == set(range(8))  # every input tile-row used
    assert set(pat.cols) == set(range(8))  # every output tile-col used
    assert 0.15 <= pat.density <= 0.35


def test_prune_dense_keeps_top_blocks():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((32, 32)).astype(np.float32) * 0.01
    w[0:8, 0:8] = 10.0  # dominant block must survive pruning
    pat, tiles = prune_dense(w, 8, 8, density=0.25)
    assert (0, 0) in set(zip(pat.rows, pat.cols))
    assert pat.n_tiles == 4
    y = sparse_matmul(jnp.eye(32), jnp.asarray(tiles), pat)
    kept = _dense_of(pat, tiles)
    np.testing.assert_allclose(np.asarray(y), kept, rtol=1e-5)


def test_pack_dense_roundtrip():
    pat = random_pattern(32, 48, 8, 8, 0.5, seed=1)
    rng = np.random.default_rng(1)
    tiles = rng.standard_normal((pat.n_tiles, 8, 8)).astype(np.float32)
    w = _dense_of(pat, tiles)
    np.testing.assert_allclose(np.asarray(pack_dense(jnp.asarray(w), pat)), tiles)


def test_pattern_hash_no_elision_collision():
    """Regression: v1 hashed ``repr()`` of the coordinate arrays, which
    numpy elides past ~1k elements — two large patterns differing only in
    the elided middle collapsed onto one plan-cache key.  v2 hashes the
    raw coordinate bytes, so they must differ."""
    R = C = 40  # 1600 tiles > the repr elision threshold
    rows = np.repeat(np.arange(R), C)
    cols = np.tile(np.arange(C), R)
    cols2 = cols.copy()
    mid = len(cols2) // 2
    cols2[mid], cols2[mid + 1] = cols2[mid + 1], cols2[mid]  # elided region
    p1 = BlockPattern(R * 4, C * 4, 4, 4, rows, cols)
    p2 = BlockPattern(R * 4, C * 4, 4, 4, rows, cols2)
    assert repr(p1.cols).count("...")  # precondition: repr really elides
    assert pattern_hash(p1) != pattern_hash(p2)
    # canonicalization: tuple- and ndarray-carrying patterns agree
    p3 = BlockPattern(R * 4, C * 4, 4, 4, tuple(rows), tuple(cols))
    assert pattern_hash(p1) == pattern_hash(p3)


def test_strategy_registry_keys_include_device(tmp_path, monkeypatch):
    """Regression: the in-process strategy registry was keyed by pattern
    hash alone, so a 'pallas' winner resolved under one backend leaked
    into processes/phases running another backend.  A plan loaded under a
    monkeypatched 'tpu' backend must not be replayed once the backend is
    'cpu' again."""
    from repro.core import cache as cachelib
    from repro.core.staging import StagingOptions

    pat = random_pattern(32, 32, 8, 8, 0.5, seed=0)
    store = cachelib.PlanCache(str(tmp_path))
    h = pattern_hash(pat)
    store.store_plan(
        cachelib.plan_key("linear", h, "tpu"),
        cachelib.TuningPlan(
            kind="linear", structure_hash=h,
            options=StagingOptions(backend="pallas", tile=(8, 8)),
            device="tpu", source="measured",
        ),
    )
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    got = choose_matmul_strategy(pat, cache=store, allow_bench=False)
    assert got == "pallas"  # the fake-TPU plan loads
    monkeypatch.undo()
    got = choose_matmul_strategy(pat, cache=store, allow_bench=False)
    assert got != "pallas"  # must re-resolve for the real backend


def test_family_churn_takes_fixed_block_without_caching(tmp_path):
    """Per-batch structure churn: after enough distinct hashes in one
    family the arbiter returns the inspection-free strategy and stops
    touching the registry and the plan cache (a never-repeating structure
    must not pollute either)."""
    from repro.core import cache as cachelib
    from repro.core.autotune import reset_structure_trackers
    from repro.sparse import linear as linmod

    reset_structure_trackers()
    store = cachelib.PlanCache(str(tmp_path))
    pats = [random_pattern(32, 32, 8, 8, 0.5, seed=s) for s in range(8)]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32))
    seen = []
    for pat in pats:
        before = store.stats()["plans"]
        strat = choose_matmul_strategy(pat, cache=store, allow_bench=False,
                                       family="churny")
        seen.append(strat)
        if strat == "fixed_block":  # arbiter short-circuit: no cache write
            assert store.stats()["plans"] == before
    assert seen[-1] == "fixed_block"
    fixed = [p for p, s in zip(pats, seen) if s == "fixed_block"]
    assert fixed and all(
        f"{pattern_hash(p)}@{jax.default_backend()}"
        not in linmod._STRATEGY_REGISTRY
        for p in fixed
    )
    # the chosen impl is numerically the same matmul
    pat = fixed[-1]
    tiles = jnp.asarray(
        rng.standard_normal((pat.n_tiles, 8, 8)).astype(np.float32)
    )
    y = linmod._MATMUL_IMPLS["fixed_block"](x, tiles, pat)
    ref = sparse_matmul(x, tiles, pat)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)
    # a STATIC family (same hash every call) keeps the staged path
    reset_structure_trackers()
    static = [
        choose_matmul_strategy(pats[0], cache=store, allow_bench=False,
                               family="static")
        for _ in range(8)
    ]
    assert "fixed_block" not in static


def test_pallas_path_matches_grouped():
    pat = random_pattern(32, 64, 8, 16, 0.5, seed=2)
    rng = np.random.default_rng(2)
    tiles = rng.standard_normal((pat.n_tiles, 8, 16)).astype(np.float32)
    x = rng.standard_normal((6, 32)).astype(np.float32)
    y1 = sparse_matmul(jnp.asarray(x), jnp.asarray(tiles), pat)
    y2 = sparse_matmul_pallas(jnp.asarray(x), jnp.asarray(tiles), pat,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
