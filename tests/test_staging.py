"""Staged kernels: every backend vs the densify oracle; caching; hybrid."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # keep deterministic cases running without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core import vbr as vbrlib
from repro.core.staging import (
    StagingOptions,
    cache_info,
    clear_cache,
    partition_block_rows,
    stage_block_op,
    stage_spmm,
    stage_spmv,
)
from repro.core.dsl import loopgen

BACKENDS = ["unrolled", "grouped", "gather", "pallas"]


def _mk(seed=0, rows=67, cols=53, rs=6, cs=5, nb=14, sp=0.25, uniform=False):
    return vbrlib.synthesize(rows, cols, rs, cs, nb, sp, uniform, seed)


@pytest.mark.parametrize("backend", BACKENDS)
def test_spmv_backends_vs_oracle(backend):
    v = _mk()
    x = np.random.default_rng(0).standard_normal(v.shape[1]).astype(np.float32)
    ref = v.to_dense() @ x
    k = stage_spmv(v, StagingOptions(backend=backend, tile=(8, 16), interpret=True))
    y = np.asarray(k(jnp.asarray(v.val), jnp.asarray(x)))
    np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_spmm_backends_vs_oracle(backend):
    v = _mk(seed=1)
    X = np.random.default_rng(1).standard_normal((v.shape[1], 24)).astype(np.float32)
    ref = v.to_dense() @ X
    k = stage_spmm(
        v, 24, StagingOptions(backend=backend, tile=(8, 16), spmm_bn=8, interpret=True)
    )
    y = np.asarray(k(jnp.asarray(v.val), jnp.asarray(X)))
    np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 1000),
    rows=st.integers(8, 80),
    cols=st.integers(8, 80),
    rs=st.integers(1, 6),
    cs=st.integers(1, 6),
    sp=st.floats(0.0, 0.8),
    backend=st.sampled_from(["unrolled", "grouped"]),
)
def test_spmv_property(seed, rows, cols, rs, cs, sp, backend):
    v = vbrlib.synthesize(rows, cols, rs, cs, max(1, rs * cs // 2), sp, False, seed)
    x = np.random.default_rng(seed).standard_normal(cols).astype(np.float32)
    k = stage_spmv(v, StagingOptions(backend=backend))
    y = np.asarray(k(jnp.asarray(v.val), jnp.asarray(x)))
    np.testing.assert_allclose(y, v.to_dense() @ x, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_spmv_dtypes(dtype):
    v = _mk(seed=2)
    x32 = np.random.default_rng(2).standard_normal(v.shape[1]).astype(np.float32)
    ref = v.to_dense() @ x32
    k = stage_spmv(v, StagingOptions(backend="grouped", dtype=jnp.dtype(dtype)))
    y = np.asarray(
        k(jnp.asarray(v.val), jnp.asarray(x32)), dtype=np.float32
    )
    tol = 1e-4 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(y, ref, rtol=tol, atol=tol)


def test_density_threshold_hybrid():
    """Listing 3: very sparse blocks go through the unrolled COO tail."""
    v = _mk(seed=3, sp=0.9, nb=20)
    x = np.random.default_rng(3).standard_normal(v.shape[1]).astype(np.float32)
    k = stage_spmv(v, StagingOptions(backend="grouped", density_threshold=0.5))
    assert k.coo is not None  # some blocks routed to COO
    assert len(k.descs) < 20  # and fewer dense blocks remain
    y = np.asarray(k(jnp.asarray(v.val), jnp.asarray(x)))
    np.testing.assert_allclose(y, v.to_dense() @ x, rtol=2e-4, atol=2e-4)


def test_executable_cache_same_pattern():
    clear_cache()
    v = _mk(seed=4)
    k1 = stage_spmv(v, StagingOptions(backend="grouped"))
    # same structure, different values => cache hit (compile once/run many)
    v2 = vbrlib.VBR(**{**v.__dict__})
    v2.val = v.val * 2.0
    k2 = stage_spmv(v2, StagingOptions(backend="grouped"))
    assert k1 is k2
    info = cache_info()
    assert info["hits"] == 1 and info["misses"] == 1
    x = np.ones(v.shape[1], np.float32)
    y1 = np.asarray(k1(jnp.asarray(v.val), jnp.asarray(x)))
    y2 = np.asarray(k2(jnp.asarray(v2.val), jnp.asarray(x)))
    np.testing.assert_allclose(y2, 2 * y1, rtol=1e-5)


def test_prepack_amortization():
    v = _mk(seed=5)
    x = np.random.default_rng(5).standard_normal(v.shape[1]).astype(np.float32)
    k = stage_spmv(
        v, StagingOptions(backend="pallas", tile=(8, 16), interpret=True, prepack=True)
    )
    tiles = k.pack(jnp.asarray(v.val))
    y = np.asarray(k(tiles, jnp.asarray(x)))
    np.testing.assert_allclose(y, v.to_dense() @ x, rtol=2e-4, atol=2e-4)


def test_partition_block_rows_balance():
    """Paper IV-D: greedy grouping balances nnz-block load."""
    v = _mk(seed=6, rows=200, cols=200, rs=20, cs=10, nb=80)
    bins = partition_block_rows(v, 4)
    sizes = np.zeros(v.num_block_rows, dtype=np.int64)
    for t in v.blocks():
        sizes[t.block_row] += t.size
    loads = sorted(sum(int(sizes[a]) for a in b) for b in bins)
    assert loads[-1] <= 2 * max(loads[0], 1) + int(sizes.max())
    assert sorted(a for b in bins for a in b) == sorted(
        set(a for b in bins for a in b)
    )


def test_stage_block_op_custom():
    """Extensibility: arbitrary user op staged over all blocks."""
    v = _mk(seed=7)

    def scale_rowsum(r1, r2, blk, x, out):
        def body(i, j):
            out[i] += blk[(j - r2.start) * len(r1) + (i - r1.start)] * x[j]

        loopgen(r1, lambda i: loopgen(r2, lambda j: body(i, j)))

    fn = stage_block_op(v, scale_rowsum, extra_arrays=("x",))
    x = np.random.default_rng(7).standard_normal(v.shape[1]).astype(np.float32)
    out = fn(jnp.asarray(v.val), jnp.asarray(x), jnp.zeros(v.shape[0], jnp.float32))
    np.testing.assert_allclose(np.asarray(out), v.to_dense() @ x, rtol=2e-4, atol=2e-4)


def test_inspection_time_recorded():
    clear_cache()
    v = _mk(seed=8)
    k = stage_spmv(v, StagingOptions(backend="grouped"))
    k.compile(
        jax.ShapeDtypeStruct(v.val.shape, jnp.float32),
        jax.ShapeDtypeStruct((v.shape[1],), jnp.float32),
    )
    assert k.stage0_time > 0 and k.compile_time > 0
    assert k.inspection_time == k.stage0_time + k.compile_time
