"""Unit tests for the HLO text analyzer (roofline measurement tool)."""
from repro.launch.hlo_stats import (
    analyze_hlo,
    collective_stats,
    parse_shape_bytes,
    _group_stride,
    _wire_factor,
)

HLO = """
HloModule test, is_scheduled=true

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %g = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %i0 = s32[] constant(0)
  %init = (s32[], f32[8,8]{1,0}) tuple(%i0, %x)
  %w = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ag = f32[16,8]{1,0} all-gather(%x), replica_groups=[2,2]<=[2,2]T(1,0), dimensions={0}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[8,8]{1,0}") == 256
    assert parse_shape_bytes("(s32[], bf16[4,2])") == 4 + 16
    assert parse_shape_bytes("pred[]") == 1


def test_while_trip_count_multiplies():
    r = analyze_hlo(HLO)
    assert r["flops"] == 5 * 2 * 8 * 8 * 8  # dot in body x trip 5


def test_collective_accounting():
    c = collective_stats(HLO)
    ar = c["all-reduce"]
    assert ar["count"] == 5
    assert ar["bytes"] == 5 * 256
    assert ar["wire_bytes"] == 5 * 256 * 2 * 3 / 4  # ring AR, n=4
    ag = c["all-gather"]
    assert ag["count"] == 1
    assert ag["bytes"] == 512
    assert ag["wire_bytes"] == 512 * 0.5  # n=2


def test_wire_factors():
    assert _wire_factor("all-reduce", 4) == 1.5
    assert _wire_factor("all-gather", 4) == 0.75
    assert _wire_factor("collective-permute", 2) == 1.0
    assert _wire_factor("all-reduce", 1) == 0.0


def test_group_stride_detects_dcn():
    # explicit groups crossing pods (stride 256)
    line = "x = f32[4] all-reduce(%y), replica_groups={{0,256},{1,257}}"
    assert _group_stride(line) == 256
    # iota form: [256,2]<=[2,256]T(1,0) => groups pair (i, i+256)
    line2 = "x = f32[4] all-reduce(%y), replica_groups=[256,2]<=[2,256]T(1,0)"
    assert _group_stride(line2) == 256
    # within-pod model axis groups: stride 1
    line3 = "x = f32[4] all-reduce(%y), replica_groups=[32,16]<=[512]"
    assert _group_stride(line3) == 1


DUS_HLO = """
HloModule dus, is_scheduled=true

ENTRY %main (buf: f32[64,64], upd: f32[1,64]) -> f32[64,64] {
  %buf = f32[64,64]{1,0} parameter(0)
  %upd = f32[1,64]{1,0} parameter(1)
  %z = s32[] constant(3)
  ROOT %d = f32[64,64]{1,0} dynamic-update-slice(%buf, %upd, %z, %z)
}
"""


def test_dus_counts_update_slice_not_buffer():
    r = analyze_hlo(DUS_HLO)
    # params read once (64*64*4 + 1*64*4) + DUS write of the UPDATE slice
    assert r["hbm_bytes_est"] == 64 * 64 * 4 + 64 * 4 + 64 * 4


def test_while_plumbing_not_traffic():
    r = analyze_hlo(HLO)
    # entry param (256) + body interior ops each trip; the while op's own
    # tuple output must not be charged
    assert r["hbm_bytes_est"] < 5 * (256 * 3) + 1024


def test_top_collectives_reports_sources():
    from repro.launch.hlo_stats import top_collectives

    rows = top_collectives(HLO)
    assert rows and rows[0]["kind"] == "all-reduce"
    assert rows[0]["trips"] == 5
