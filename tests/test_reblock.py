"""Reblocking, structure detection, and the DIA-hybrid backend.

Covers the inspection layer end to end (docs/inspection.md):
``core.inspect`` classification, the ``core.reblock`` Ahrens–Boman DP
(checked against brute force on tiny axes), spec application and kernel
equivalence, the ``kernels.dia_hybrid`` SpMV path, the autotuner's
``include_reblock`` candidate space (cold tune / warm zero-rederivation),
the cost-model corpus exclusion bugfix, and the ``sparse.linear``
``include_dia`` exposure.
"""
import itertools
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import cache as cachelib
from repro.core import inspect as inspectlib
from repro.core import reblock as rblib
from repro.core import vbr as vbrlib
from repro.core.autotune import (
    autotune,
    autotune_stage,
    autotune_stats,
    reset_autotune_stats,
)
from repro.core.staging import StagingOptions, clear_cache, stage_spmm, stage_spmv
from repro.kernels.dia_hybrid import stage_dia_hybrid

TOL = dict(atol=3e-5, rtol=3e-5)


@pytest.fixture(autouse=True)
def _fresh(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    clear_cache()
    reset_autotune_stats()
    rblib.reset_reblock_stats()
    yield
    clear_cache()


# --------------------------------------------------------------------- #
# structure builders
# --------------------------------------------------------------------- #
def banded_dense(n=48, bw=3, seed=0):
    rng = np.random.default_rng(seed)
    dense = np.zeros((n, n), np.float32)
    for i in range(n):
        for j in range(max(0, i - bw), min(n, i + bw + 1)):
            dense[i, j] = rng.standard_normal()
    return dense


def misblocked_banded(n=48, bw=3, step=2, seed=0):
    """A narrow band stored under uniform splits that ignore the band —
    the structure the reblocking DP repairs."""
    splits = list(range(0, n + 1, step))
    return vbrlib.from_dense(banded_dense(n, bw, seed), splits, splits)


def arrow_vbr(n=60, seed=1):
    rng = np.random.default_rng(seed)
    dense = np.zeros((n, n), np.float32)
    splits = [0, 12, 20, 28, 40, 48, 60]
    R = len(splits) - 1
    for b in range(R):
        dense[: splits[1], splits[b] : splits[b + 1]] = rng.standard_normal(
            (splits[1], splits[b + 1] - splits[b])
        )
    for a in range(R):
        dense[splits[a] : splits[a + 1], : splits[1]] = rng.standard_normal(
            (splits[a + 1] - splits[a], splits[1])
        )
        dense[splits[a] : splits[a + 1], splits[a] : splits[a + 1]] = (
            rng.standard_normal(
                (splits[a + 1] - splits[a], splits[a + 1] - splits[a])
            )
        )
    return vbrlib.from_dense(dense, splits, splits)


# --------------------------------------------------------------------- #
# detection (core.inspect)
# --------------------------------------------------------------------- #
def test_detect_banded():
    info = inspectlib.detect_structure(misblocked_banded())
    assert info.structure_class == "banded"
    assert info.bandwidth == 3
    assert info.bandwidth_frac <= inspectlib.BAND_FRAC
    assert info.wants_dia  # a full narrow band is also densely diagonal


def test_detect_arrow():
    info = inspectlib.detect_structure(arrow_vbr())
    assert info.structure_class == "arrow"
    assert info.arrow_score >= inspectlib.ARROW_SCORE


def test_detect_partially_diagonal():
    """Dense main diagonal plus scattered off-band noise: diagonal
    occupancy qualifies, bandwidth does not."""
    n = 64
    rng = np.random.default_rng(3)
    dense = np.diag(rng.standard_normal(n).astype(np.float32))
    ii = rng.integers(0, n, 40)
    jj = rng.integers(0, n, 40)
    dense[ii, jj] += rng.standard_normal(40).astype(np.float32)
    splits = list(range(0, n + 1, 4))
    info = inspectlib.detect_structure(vbrlib.from_dense(dense, splits, splits))
    assert info.structure_class == "partially_diagonal"
    assert 0 in info.dense_offsets
    assert info.wants_dia


def test_detect_random_block():
    v = vbrlib.synthesize(120, 100, 10, 8, 30, 0.25, uniform=False, seed=42)
    info = inspectlib.detect_structure(v)
    assert info.structure_class == "random_block"
    assert not info.wants_dia


def test_detect_empty():
    v = vbrlib.from_dense(np.zeros((12, 12), np.float32), [0, 6, 12], [0, 6, 12])
    assert inspectlib.detect_structure(v).structure_class == "empty"


def test_detect_pattern_banded():
    from repro.sparse.linear import BlockPattern

    R = C = 10
    rows, cols = zip(*[(i, j) for i in range(R)
                       for j in (i - 1, i, i + 1) if 0 <= j < C])
    pat = BlockPattern(R * 4, C * 4, 4, 4, rows, cols)
    info = inspectlib.detect_pattern(pat)
    assert info.structure_class == "banded"
    assert info.wants_dia


# --------------------------------------------------------------------- #
# the partition DP (core.reblock)
# --------------------------------------------------------------------- #
def _brute_force_1d(coord, ortho_block, ortho_widths, n, alpha):
    """Exhaustive minimum over every contiguous row partition (tiny n)."""
    best = np.inf
    for bits in itertools.product([0, 1], repeat=n - 1):
        pts = [0] + [i + 1 for i, b in enumerate(bits) if b] + [n]
        cost = 0.0
        for a, b in zip(pts[:-1], pts[1:]):
            mask = (coord >= a) & (coord < b)
            hit = np.unique(ortho_block[mask])
            cost += alpha * len(hit) + (b - a) * ortho_widths[hit].sum()
        best = min(best, cost)
    return best


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_dp_matches_brute_force(seed):
    """On axes small enough to enumerate, the DP's optimum equals the
    exhaustive minimum over all contiguous partitions."""
    n = 7
    rng = np.random.default_rng(seed)
    nnz = 12
    r = rng.integers(0, n, nnz)
    c = rng.integers(0, n, nnz)
    cpntr = np.array([0, 3, 5, n])
    ortho_block = np.searchsorted(cpntr, c, side="right") - 1
    ortho_widths = np.diff(cpntr).astype(np.float64)
    alpha = 4.0
    pts, cost = rblib.optimal_partition_1d(
        r, ortho_block, ortho_widths,
        base_pts=np.arange(n + 1), alpha=alpha, max_span=n,
    )
    assert cost == pytest.approx(
        _brute_force_1d(r, ortho_block, ortho_widths, n, alpha)
    )
    # the returned split points must reproduce the returned cost
    check, _, _ = rblib.partition_cost(
        r, c, np.asarray(pts), cpntr, alpha=alpha
    )
    assert check == pytest.approx(cost)


def test_partition_cost_hand_checked():
    """2x2 grid, 3 stored cells, hand-computed Ahrens–Boman cost."""
    rows = np.array([0, 1, 2, 3])
    cols = np.array([0, 1, 2, 0])
    rpntr = np.array([0, 2, 4])
    cpntr = np.array([0, 2, 4])
    # cells: (0,0) 2x2, (1,1) 2x2, (1,0) 2x2 -> 3 blocks, 12 stored entries
    cost, nb, stored = rblib.partition_cost(rows, cols, rpntr, cpntr, alpha=10.0)
    assert (nb, stored) == (3, 12)
    assert cost == pytest.approx(10.0 * 3 + 12)


def test_propose_recovers_band_blocking():
    """The DP must repair the misblocked band: strictly cheaper than the
    as-given 2-wide scalar blocking, and correct after application."""
    v = misblocked_banded()
    specs = rblib.propose_reblockings(v, device="cpu")
    assert specs and specs[0].strategy == "dp"
    spec = specs[0]
    assert spec.cost < rblib.MIN_GAIN * spec.base_cost
    rvbr, gather = rblib.apply_reblock(v, spec)
    np.testing.assert_allclose(rvbr.to_dense(), v.to_dense())
    assert vbrlib.structure_hash(rvbr) == spec.structure_hash


def test_propose_skips_well_blocked():
    """A structure already at (near-)optimal blocking yields no dp
    proposal — the DP result matches the as-given partition."""
    n = 48
    splits = list(range(0, n + 1, 8))
    dense = np.zeros((n, n), np.float32)
    rng = np.random.default_rng(9)
    for a in range(n // 8):  # block-diagonal, fully dense blocks
        dense[a * 8 : (a + 1) * 8, a * 8 : (a + 1) * 8] = (
            rng.standard_normal((8, 8))
        )
    v = vbrlib.from_dense(dense, splits, splits)
    specs = rblib.propose_reblockings(v, device="cpu")
    assert not [s for s in specs if s.strategy == "dp"]


def test_aligned_proposal_is_tile_aligned():
    v = misblocked_banded(n=64, bw=4, step=2)
    specs = rblib.propose_reblockings(v, device="cpu", include_aligned=True)
    aligned = [s for s in specs if s.strategy.startswith("aligned")]
    assert aligned
    tm, tk = rblib.ALIGNED_TILE
    rp = np.asarray(aligned[0].rpntr)
    assert all(p % tm == 0 or p == v.shape[0] for p in rp)
    assert aligned[0].fill_ratio <= rblib.MAX_ALIGNED_FILL
    rvbr, _ = rblib.apply_reblock(v, aligned[0])
    np.testing.assert_allclose(rvbr.to_dense(), v.to_dense())


def test_val_gather_remaps_new_values():
    """The staged reblocked kernel reads the ORIGINAL val layout: new
    values written into the original layout must flow through."""
    v = misblocked_banded()
    spec = rblib.propose_reblockings(v, device="cpu")[0]
    k = rblib.stage_reblocked(v, spec, StagingOptions(), "spmv", None)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(v.shape[1]).astype(np.float32))
    new_val = rng.standard_normal(v.val.shape).astype(np.float32)
    v2 = vbrlib.VBR(shape=v.shape, val=new_val, rpntr=v.rpntr, cpntr=v.cpntr,
                    bindx=v.bindx, bpntrb=v.bpntrb, bpntre=v.bpntre,
                    indx=v.indx)
    got = np.asarray(k(jnp.asarray(new_val), x))
    np.testing.assert_allclose(got, v2.to_dense() @ np.asarray(x), **TOL)


def test_reblocked_spmm_matches_dense():
    v = misblocked_banded()
    spec = rblib.propose_reblockings(v, device="cpu")[0]
    rng = np.random.default_rng(6)
    X = jnp.asarray(rng.standard_normal((v.shape[1], 5)).astype(np.float32))
    k = rblib.stage_reblocked(v, spec, StagingOptions(), "spmm", 5)
    got = np.asarray(k(jnp.asarray(v.val), X))
    np.testing.assert_allclose(got, v.to_dense() @ np.asarray(X), **TOL)


def test_apply_reblock_rejects_stale_spec():
    v = misblocked_banded()
    other = misblocked_banded(seed=99, bw=8)  # wider band: different cells
    spec = rblib.propose_reblockings(v, device="cpu")[0]
    with pytest.raises(ValueError, match="stale"):
        rblib.apply_reblock(other, spec)


# --------------------------------------------------------------------- #
# DIA-hybrid SpMV (kernels.dia_hybrid)
# --------------------------------------------------------------------- #
def test_dia_hybrid_matches_dense_banded():
    v = misblocked_banded()
    k = stage_dia_hybrid(v)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal(v.shape[1]).astype(np.float32))
    got = np.asarray(k(jnp.asarray(v.val), x))
    np.testing.assert_allclose(got, v.to_dense() @ np.asarray(x), **TOL)
    assert k.num_diagonals == 7
    # off-band STORED slots (the 2x2 blocks straddling the band edge)
    # must land in the remainder — they are live parameter slots
    assert k.remainder_nnz > 0


def test_dia_hybrid_scalar_band_no_remainder():
    """Scalar-blocked pure band: every stored slot sits on a dense
    diagonal, so the remainder is empty and the kernel is all-DIA."""
    v = misblocked_banded(step=1)
    k = stage_dia_hybrid(v)
    assert k.remainder_nnz == 0
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal(v.shape[1]).astype(np.float32))
    got = np.asarray(k(jnp.asarray(v.val), x))
    np.testing.assert_allclose(got, v.to_dense() @ np.asarray(x), **TOL)


def test_dia_hybrid_with_remainder():
    """Arrow: diagonals capture the band, the hub goes to the staged-VBR
    remainder — both halves must add up to the dense product."""
    v = arrow_vbr()
    info = inspectlib.detect_structure(v)
    assert info.wants_dia
    k = stage_dia_hybrid(v)
    assert k.remainder_nnz > 0
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal(v.shape[1]).astype(np.float32))
    got = np.asarray(k(jnp.asarray(v.val), x))
    np.testing.assert_allclose(got, v.to_dense() @ np.asarray(x), **TOL)


def test_dia_hybrid_non_square():
    n, m = 40, 56
    rng = np.random.default_rng(10)
    dense = np.zeros((n, m), np.float32)
    for i in range(n):
        for j in range(max(0, i - 2), min(m, i + 3)):
            dense[i, j] = rng.standard_normal()
    v = vbrlib.from_dense(dense, list(range(0, n + 1, 4)),
                          list(range(0, m + 1, 4)))
    k = stage_dia_hybrid(v, offsets=(-2, -1, 0, 1, 2))
    x = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    got = np.asarray(k(jnp.asarray(v.val), x))
    np.testing.assert_allclose(got, dense @ np.asarray(x), **TOL)


def test_dia_hybrid_rejects_undiagonal():
    v = vbrlib.synthesize(120, 100, 10, 8, 30, 0.25, uniform=False, seed=42)
    with pytest.raises(ValueError):
        stage_dia_hybrid(v)


def test_stage_spmv_dispatches_dia_backend():
    v = misblocked_banded()
    k = stage_spmv(v, StagingOptions(backend="dia_hybrid"))
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal(v.shape[1]).astype(np.float32))
    got = np.asarray(k(jnp.asarray(v.val), x))
    np.testing.assert_allclose(got, v.to_dense() @ np.asarray(x), **TOL)
    with pytest.raises(ValueError, match="SpMV-only"):
        stage_spmm(v, 4, StagingOptions(backend="dia_hybrid"))
    with pytest.raises(ValueError, match="unsharded"):
        stage_spmv(v, StagingOptions(backend="dia_hybrid"), shards=2)


# --------------------------------------------------------------------- #
# autotuner integration (the tentpole contract)
# --------------------------------------------------------------------- #
def test_autotune_reblock_candidates_on_banded():
    """Acceptance: on the banded fixture pattern the extended tuner sees
    reblocked and DIA-hybrid candidates, and the key carries ``-rb``."""
    v = misblocked_banded()
    store = cachelib.PlanCache(os.environ["REPRO_CACHE_DIR"])
    plan = autotune(v, kind="spmv", cache=store, include_reblock=True,
                    warmup=0, iters=1)
    labels = set(plan.timings)
    assert "dia_hybrid" in labels
    assert any(l.startswith("reblock[dp]+") for l in labels)
    assert "reblock_fill_ratio" in plan.meta
    assert plan.meta["structure_class"] == "banded"
    assert plan.meta["dia_offsets"] == [0, -1, 1, -2, 2, -3, 3]
    # every structure-derived candidate produced a real timing (the
    # winner itself is a measured choice — benchmarks/bench_reblock.py
    # asserts the selection with proper warmup/iters)
    assert all(t > 0 for t in plan.timings.values())


def test_autotune_reblock_candidates_on_arrow():
    v = arrow_vbr()
    store = cachelib.PlanCache(os.environ["REPRO_CACHE_DIR"])
    plan = autotune(v, kind="spmv", cache=store, include_reblock=True,
                    warmup=0, iters=1)
    assert plan.meta["structure_class"] == "arrow"
    assert "dia_hybrid" in plan.timings


def test_autotune_warm_rederives_nothing():
    """Warm restart: plan served from disk with zero benchmarks AND zero
    detection/DP work (the inspection pipeline runs only on cold tunes)."""
    v = misblocked_banded()
    store = cachelib.PlanCache(os.environ["REPRO_CACHE_DIR"])
    k1 = autotune_stage(v, kind="spmv", cache=store, include_reblock=True,
                        warmup=0, iters=1)
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.standard_normal(v.shape[1]).astype(np.float32))
    ref = np.asarray(k1(jnp.asarray(v.val), x))

    clear_cache()
    reset_autotune_stats()
    rblib.reset_reblock_stats()
    k2 = autotune_stage(v, kind="spmv", cache=store, include_reblock=True)
    got = np.asarray(k2(jnp.asarray(v.val), x))
    np.testing.assert_allclose(got, ref, **TOL)
    stats = autotune_stats()
    assert stats["cache_hits"] == 1
    assert stats["benchmarks"] == 0
    assert rblib.reblock_stats()["dp_runs"] == 0


def test_autotune_reblock_key_does_not_alias_base():
    """The same structure tuned with and without ``include_reblock`` gets
    two distinct plans — the extended space must never leak into callers
    that didn't opt in."""
    v = misblocked_banded()
    store = cachelib.PlanCache(os.environ["REPRO_CACHE_DIR"])
    autotune(v, kind="spmv", cache=store, include_reblock=True,
             warmup=0, iters=1)
    reset_autotune_stats()
    plan_base = autotune(v, kind="spmv", cache=store, warmup=0, iters=1)
    assert autotune_stats()["cache_misses"] == 1  # not served from -rb
    assert plan_base.reblock is None
    assert plan_base.options.backend != "dia_hybrid"
    assert not any(l.startswith("reblock[") for l in plan_base.timings)


def test_autotune_stage_reblocked_plan_roundtrip():
    """A persisted reblocked plan stages through ``autotune_stage`` on a
    fresh process (simulated by clearing in-memory caches) and matches
    dense."""
    import dataclasses

    v = misblocked_banded()
    store = cachelib.PlanCache(os.environ["REPRO_CACHE_DIR"])
    plan = autotune(v, kind="spmv", cache=store, include_reblock=True,
                    warmup=0, iters=1)
    # force a reblocked winner regardless of CPU timing noise
    spec = rblib.propose_reblockings(v, device="cpu")[0]
    key = cachelib.plan_key("spmv", vbrlib.structure_hash(v), "cpu",
                            reblock=True)
    forced = dataclasses.replace(
        plan, options=StagingOptions(backend="grouped"),
        reblock=spec.to_dict(),
    )
    store.store_plan(key, forced)
    clear_cache()
    k = autotune_stage(v, kind="spmv", cache=store, include_reblock=True)
    assert k.spec.strategy == "dp"
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal(v.shape[1]).astype(np.float32))
    got = np.asarray(k(jnp.asarray(v.val), x))
    np.testing.assert_allclose(got, v.to_dense() @ np.asarray(x), **TOL)


def test_reblocked_structure_stored_for_warm_restart():
    """When a reblocked candidate wins, the REBLOCKED structure is also
    persisted under its own hash (warm restarts re-derive nothing)."""
    import dataclasses

    v = misblocked_banded()
    store = cachelib.PlanCache(os.environ["REPRO_CACHE_DIR"])
    autotune(v, kind="spmv", cache=store, include_reblock=True,
             warmup=0, iters=1)
    spec = rblib.propose_reblockings(v, device="cpu")[0]
    assert store.load_structure(spec.structure_hash) is not None


# --------------------------------------------------------------------- #
# cost-model corpus exclusion (the satellite bugfix)
# --------------------------------------------------------------------- #
def test_corpus_excludes_reblocked_plans_without_features():
    """Regression: a measured plan that chose a reblocked candidate but
    predates the ``reblock_fill_ratio`` meta feature must NOT train the
    cost model (its timings describe the reblocked structure, its
    features the original — a silent feedback loop)."""
    import dataclasses

    from repro.core import cost_model as cmlib

    v = misblocked_banded()
    store = cachelib.PlanCache(os.environ["REPRO_CACHE_DIR"])
    plan = autotune(v, kind="spmv", cache=store, include_reblock=True,
                    warmup=0, iters=1)
    assert "reblock_fill_ratio" in plan.meta

    spec = rblib.propose_reblockings(v, device="cpu")[0]
    legacy_meta = {k: val for k, val in plan.meta.items()
                   if k != "reblock_fill_ratio"}
    legacy = dataclasses.replace(plan, reblock=spec.to_dict(),
                                 meta=legacy_meta)
    ok = dataclasses.replace(plan, reblock=spec.to_dict())
    store.store_plan("spmv-legacy-cpu-rb", legacy)
    store.store_plan("spmv-ok-cpu-rb", ok)
    rows = cmlib.corpus(store, "cpu", "spmv")
    stored = {id(p) for p in rows}
    assert not any(p.reblock is not None
                   and "reblock_fill_ratio" not in p.meta for p in rows)
    assert any(p.reblock is not None for p in rows)  # feature-complete ones stay
    del stored


def test_feature_vector_includes_structure_features():
    from repro.core import cost_model as cmlib

    assert "bandwidth_frac" in cmlib.FEATURE_NAMES
    assert "diag_occupancy" in cmlib.FEATURE_NAMES
    assert "reblock_fill" in cmlib.FEATURE_NAMES
    v = misblocked_banded()
    feats = cmlib.vbr_features(v, "spmv")
    assert len(feats) == len(cmlib.FEATURE_NAMES)
    names = list(cmlib.FEATURE_NAMES)
    assert feats[names.index("bandwidth_frac")] == pytest.approx(3 / 48)
    assert feats[names.index("diag_occupancy")] == pytest.approx(1.0)
    # plans without the feature degrade to neutral defaults
    legacy = cmlib.meta_features("spmv", {"shape": [8, 8], "stored_nnz": 4,
                                          "num_blocks": 1})
    assert legacy[names.index("bandwidth_frac")] == 1.0
    assert legacy[names.index("diag_occupancy")] == 0.0
    assert legacy[names.index("reblock_fill")] == 1.0


# --------------------------------------------------------------------- #
# sparse.linear exposure
# --------------------------------------------------------------------- #
def _banded_pattern(R=12, tm=4):
    from repro.sparse.linear import BlockPattern

    rows, cols = zip(*[(i, j) for i in range(R)
                       for j in (i - 1, i, i + 1) if 0 <= j < R])
    return BlockPattern(R * tm, R * tm, tm, tm, rows, cols)


def test_linear_dia_hybrid_matches_grouped():
    from repro.sparse.linear import _MATMUL_IMPLS, pack_dense

    pat = _banded_pattern()
    rng = np.random.default_rng(14)
    W = np.zeros((pat.d_in, pat.d_out), np.float32)
    for r, c in zip(pat.rows, pat.cols):
        W[r * pat.tm:(r + 1) * pat.tm, c * pat.tk:(c + 1) * pat.tk] = (
            rng.standard_normal((pat.tm, pat.tk))
        )
    tiles = jnp.asarray(pack_dense(jnp.asarray(W), pat))
    x = jnp.asarray(rng.standard_normal((3, pat.d_in)).astype(np.float32))
    got = np.asarray(_MATMUL_IMPLS["dia_hybrid"](x, tiles, pat))
    np.testing.assert_allclose(got, np.asarray(x) @ W, **TOL)


def test_linear_dia_hybrid_grads():
    from repro.sparse.linear import _MATMUL_IMPLS, pack_dense, sparse_matmul

    pat = _banded_pattern(R=6)
    rng = np.random.default_rng(15)
    tiles = jnp.asarray(
        rng.standard_normal((pat.n_tiles, pat.tm, pat.tk)).astype(np.float32)
    )
    x = jnp.asarray(rng.standard_normal((2, pat.d_in)).astype(np.float32))
    f_dia = lambda t: _MATMUL_IMPLS["dia_hybrid"](x, t, pat).sum()
    f_ref = lambda t: sparse_matmul(x, t, pat).sum()
    np.testing.assert_allclose(
        np.asarray(jax.grad(f_dia)(tiles)),
        np.asarray(jax.grad(f_ref)(tiles)), **TOL,
    )


def test_choose_strategy_include_dia_keys_and_candidates():
    from repro.sparse import linear as linlib

    pat = _banded_pattern()
    store = cachelib.PlanCache(os.environ["REPRO_CACHE_DIR"])
    linlib._STRATEGY_REGISTRY.clear()
    s = linlib.choose_matmul_strategy(pat, cache=store, include_dia=True,
                                      warmup=0, iters=1)
    assert s in ("grouped", "dia_hybrid")
    phash = linlib.pattern_hash(pat)
    device = jax.default_backend()
    rb_key = cachelib.plan_key("linear", phash, device, reblock=True)
    plan = store.load_plan(rb_key)
    assert plan is not None
    assert "dia_hybrid" in plan.timings
    assert plan.meta["structure_class"] == "banded"
    # the base key is untouched: non-opted-in callers see no plan
    assert store.load_plan(cachelib.plan_key("linear", phash, device)) is None
    s_base = linlib.choose_matmul_strategy(pat, cache=store)
    assert s_base == "grouped"  # single base candidate on cpu, no bench
    linlib._STRATEGY_REGISTRY.clear()
