"""Inspection-free block-sparse op family: dsd/dds/sdd CPU-interpret
parity (forward + grads) vs the dense reference, structural edge cases,
and the dropless MoE path built on top of them."""

import numpy as np
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # keep deterministic cases running without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.kernels.bsr_ops import dds, dsd, sdd
from repro.sparse.block_csr import (
    BlockMatrix,
    mask_from_dense,
    topology_from_mask,
)

# 'pallas' runs in interpret mode here (CPU tier-1); both must agree with
# the dense reference to 1e-5
BACKENDS = ("grouped", "pallas")
TOL = dict(rtol=1e-5, atol=1e-5)


def _random_sparse(rng, Rb, Cb, bm, bn, density, pad=0):
    """A BlockMatrix with a random topology plus ``pad`` extra padding
    slots (data at padding slots is GARBAGE before from_mask zeroes it —
    ops must never read it)."""
    mask = rng.random((Rb, Cb)) < density
    nnz_max = max(int(mask.sum()) + pad, 1)
    data = rng.standard_normal((nnz_max, bm, bn)).astype(np.float32)
    sp = BlockMatrix.from_mask(
        jnp.asarray(mask), (bm, bn), data=jnp.asarray(data), nnz_max=nnz_max
    )
    return sp


# ---------------------------------------------------------------------- #
# forward parity
# ---------------------------------------------------------------------- #
@settings(max_examples=15, deadline=None)
@given(
    Rb=st.sampled_from([1, 2, 3]),
    Cb=st.sampled_from([1, 2, 4]),
    bm=st.sampled_from([4, 8]),
    bn=st.sampled_from([4, 8]),
    n=st.sampled_from([3, 8]),
    density=st.floats(0.0, 1.0),
    pad=st.sampled_from([0, 3]),
    seed=st.integers(0, 1000),
)
def test_dsd_matches_dense(Rb, Cb, bm, bn, n, density, pad, seed):
    rng = np.random.default_rng(seed)
    sp = _random_sparse(rng, Rb, Cb, bm, bn, density, pad)
    x = jnp.asarray(rng.standard_normal((Cb * bn, n)).astype(np.float32))
    ref = np.asarray(sp.to_dense() @ x)
    for backend in BACKENDS:
        y = dsd(sp, x, backend=backend)
        np.testing.assert_allclose(np.asarray(y), ref, **TOL)


@settings(max_examples=15, deadline=None)
@given(
    Rb=st.sampled_from([1, 2, 3]),
    Cb=st.sampled_from([1, 2, 4]),
    bm=st.sampled_from([4, 8]),
    bn=st.sampled_from([4, 8]),
    m=st.sampled_from([3, 8]),
    density=st.floats(0.0, 1.0),
    pad=st.sampled_from([0, 3]),
    seed=st.integers(0, 1000),
)
def test_dds_matches_dense(Rb, Cb, bm, bn, m, density, pad, seed):
    rng = np.random.default_rng(seed)
    sp = _random_sparse(rng, Rb, Cb, bm, bn, density, pad)
    x = jnp.asarray(rng.standard_normal((m, Rb * bm)).astype(np.float32))
    ref = np.asarray(x @ sp.to_dense())
    for backend in BACKENDS:
        y = dds(x, sp, backend=backend)
        np.testing.assert_allclose(np.asarray(y), ref, **TOL)


@settings(max_examples=15, deadline=None)
@given(
    Rb=st.sampled_from([1, 2, 3]),
    Cb=st.sampled_from([1, 2, 4]),
    bm=st.sampled_from([4, 8]),
    bn=st.sampled_from([4, 8]),
    k=st.sampled_from([4, 16]),
    density=st.floats(0.0, 1.0),
    pad=st.sampled_from([0, 3]),
    seed=st.integers(0, 1000),
)
def test_sdd_matches_dense(Rb, Cb, bm, bn, k, density, pad, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((Rb, Cb)) < density
    nnz_max = max(int(mask.sum()) + pad, 1)
    topo = topology_from_mask(jnp.asarray(mask), (bm, bn), nnz_max=nnz_max)
    a = jnp.asarray(rng.standard_normal((Rb * bm, k)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((k, Cb * bn)).astype(np.float32))
    keep = np.repeat(np.repeat(mask, bm, 0), bn, 1)
    ref = np.where(keep, np.asarray(a @ b), 0.0)
    for backend in BACKENDS:
        out = sdd(a, b, topo, backend=backend)
        np.testing.assert_allclose(np.asarray(out.to_dense()), ref, **TOL)
        # padding slots must come back zero (downstream .data arithmetic)
        assert not np.any(np.asarray(out.data)[~np.asarray(out.valid)])


# ---------------------------------------------------------------------- #
# gradient parity (the custom_vjp family closure)
# ---------------------------------------------------------------------- #
@settings(max_examples=8, deadline=None)
@given(
    density=st.floats(0.1, 1.0),
    backend=st.sampled_from(list(BACKENDS)),
    seed=st.integers(0, 1000),
)
def test_dsd_grads_match_dense(density, backend, seed):
    rng = np.random.default_rng(seed)
    sp = _random_sparse(rng, 3, 2, 4, 8, density, pad=2)
    x = jnp.asarray(rng.standard_normal((16, 5)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((12, 5)).astype(np.float32))

    f = lambda d, x: (dsd(sp.with_data(d), x, backend=backend) * w).sum()
    ref = lambda d, x: ((sp.with_data(d).to_dense() @ x) * w).sum()
    gd, gx = jax.grad(f, argnums=(0, 1))(sp.data, x)
    rd, rx = jax.grad(ref, argnums=(0, 1))(sp.data, x)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(rd), **TOL)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), **TOL)


@settings(max_examples=8, deadline=None)
@given(
    density=st.floats(0.1, 1.0),
    backend=st.sampled_from(list(BACKENDS)),
    seed=st.integers(0, 1000),
)
def test_dds_grads_match_dense(density, backend, seed):
    rng = np.random.default_rng(seed)
    sp = _random_sparse(rng, 3, 2, 4, 8, density, pad=2)
    x = jnp.asarray(rng.standard_normal((5, 12)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((5, 16)).astype(np.float32))

    f = lambda x, d: (dds(x, sp.with_data(d), backend=backend) * w).sum()
    ref = lambda x, d: ((x @ sp.with_data(d).to_dense()) * w).sum()
    gx, gd = jax.grad(f, argnums=(0, 1))(x, sp.data)
    rx, rd = jax.grad(ref, argnums=(0, 1))(x, sp.data)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), **TOL)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(rd), **TOL)


@settings(max_examples=8, deadline=None)
@given(
    density=st.floats(0.1, 1.0),
    backend=st.sampled_from(list(BACKENDS)),
    seed=st.integers(0, 1000),
)
def test_sdd_grads_match_dense(density, backend, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((3, 2)) < density
    topo = topology_from_mask(jnp.asarray(mask), (4, 8),
                              nnz_max=int(mask.sum()) + 2)
    a = jnp.asarray(rng.standard_normal((12, 5)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((5, 16)).astype(np.float32))
    w = jnp.asarray(
        rng.standard_normal(np.asarray(topo.data.shape)).astype(np.float32)
    )
    keep = jnp.asarray(np.repeat(np.repeat(mask, 4, 0), 8, 1))
    # same cotangent, expressed densely for the reference
    wd = topo.with_data(w).to_dense()

    f = lambda a, b: (sdd(a, b, topo, backend=backend).data * w).sum()
    ref = lambda a, b: (jnp.where(keep, a @ b, 0.0) * wd).sum()
    ga, gb = jax.grad(f, argnums=(0, 1))(a, b)
    ra, rb = jax.grad(ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ra), **TOL)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rb), **TOL)


# ---------------------------------------------------------------------- #
# structural edge cases
# ---------------------------------------------------------------------- #
def test_empty_topology():
    """All-False mask (empty expert / all tokens dropped): every op is a
    well-defined zero, not an error — the padding slot carries it."""
    mask = jnp.zeros((2, 3), bool)
    sp = BlockMatrix.from_mask(mask, (4, 4), nnz_max=2)
    x = jnp.ones((12, 5))
    for backend in BACKENDS:
        assert not np.any(np.asarray(dsd(sp, x, backend=backend)))
        assert not np.any(np.asarray(dds(jnp.ones((5, 8)), sp,
                                         backend=backend)))
        out = sdd(jnp.ones((8, 6)), jnp.ones((6, 12)), sp, backend=backend)
        assert not np.any(np.asarray(out.data))
    assert int(sp.n_blocks) == 0


def test_single_block():
    """1x1 block grid — the degenerate smallest topology."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((4, 4)).astype(np.float32)
    sp = BlockMatrix.from_dense(jnp.asarray(a), (4, 4))
    x = jnp.asarray(rng.standard_normal((4, 3)).astype(np.float32))
    for backend in BACKENDS:
        np.testing.assert_allclose(
            np.asarray(dsd(sp, x, backend=backend)), a @ np.asarray(x), **TOL
        )


def test_empty_block_rows_are_zeroed():
    """Rows with no blocks must come back exactly zero on every backend
    (the pallas accumulation schedule never visits them)."""
    mask = jnp.asarray(np.array([[0, 1], [0, 0], [1, 0]], bool))
    rng = np.random.default_rng(3)
    data = jnp.asarray(rng.standard_normal((4, 4, 4)).astype(np.float32))
    sp = BlockMatrix.from_mask(mask, (4, 4), data=data, nnz_max=4)
    x = jnp.asarray(rng.standard_normal((8, 6)).astype(np.float32))
    ref = np.asarray(sp.to_dense() @ x)
    assert not ref[4:8].any()  # middle block row is empty
    for backend in BACKENDS:
        np.testing.assert_allclose(
            np.asarray(dsd(sp, x, backend=backend)), ref, **TOL
        )


def test_construction_is_traceable():
    """The inspection-free claim: topology derivation from a TRACED mask
    works under jit (no host round-trip), and retraces are not needed
    when only the mask values change."""
    traces = []

    @jax.jit
    def f(dense, x):
        traces.append(None)
        mask = mask_from_dense(dense, (4, 4))
        sp = BlockMatrix.from_dense(dense, (4, 4), nnz_max=6)
        assert isinstance(sp.row_indices, jax.core.Tracer)
        return dsd(sp, x, backend="grouped"), mask

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 3)).astype(np.float32))
    for seed in (0, 1):
        r = np.random.default_rng(seed)
        dense = r.standard_normal((8, 8)).astype(np.float32)
        dense[r.random((8, 8)) < 0.5] = 0.0
        blocks = dense.reshape(2, 4, 2, 4)
        dense = np.where(
            np.abs(blocks).sum((1, 3), keepdims=True) > 2, blocks, 0.0
        ).reshape(8, 8)
        y, _ = f(jnp.asarray(dense), x)
        np.testing.assert_allclose(np.asarray(y), dense @ np.asarray(x),
                                   **TOL)
    assert len(traces) == 1  # same nnz_max bound => no retrace


def test_transpose_roundtrip():
    rng = np.random.default_rng(7)
    sp = _random_sparse(rng, 3, 4, 4, 8, 0.5, pad=3)
    np.testing.assert_allclose(
        np.asarray(sp.transpose().to_dense()), np.asarray(sp.to_dense()).T
    )
    np.testing.assert_allclose(
        np.asarray(sp.transpose().transpose().to_dense()),
        np.asarray(sp.to_dense()),
    )


# ---------------------------------------------------------------------- #
# dropless MoE on top of the family
# ---------------------------------------------------------------------- #
def _moe_cfg(dropless, ffn_type="swiglu", capacity_factor=16.0):
    from repro.models.config import (
        LayerSpec,
        ModelConfig,
        MoEConfig,
        uniform_groups,
    )

    moe = MoEConfig(
        num_experts=4, top_k=2, d_ff=32, capacity_factor=capacity_factor,
        dropless=dropless, dropless_block=8,
    )
    return ModelConfig(
        name="t", family="moe", d_model=16, n_heads=2, n_kv_heads=2,
        head_dim=8, d_ff=32, vocab_size=64,
        groups=uniform_groups(1, LayerSpec(ffn="moe")),
        ffn_type=ffn_type, moe=moe,
    )


@settings(max_examples=6, deadline=None)
@given(
    ffn_type=st.sampled_from(["swiglu", "relu2"]),
    seed=st.integers(0, 100),
)
def test_dropless_moe_matches_capacity_path(ffn_type, seed):
    """The dropless (block-sparse FFN) path must match the capacity-buffer
    path exactly on undropped tokens; capacity_factor=16 means the
    reference drops nothing, so every token must agree — forward, aux
    loss, and grads."""
    from repro.models.moe import moe_apply, moe_init

    cfg_d = _moe_cfg(True, ffn_type)
    cfg_c = _moe_cfg(False, ffn_type)
    p = moe_init(jax.random.PRNGKey(seed), cfg_d)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 12, 16))
    yd, ad = jax.jit(lambda p, x: moe_apply(p, x, cfg_d))(p, x)
    yc, ac = moe_apply(p, x, cfg_c)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yc), **TOL)
    np.testing.assert_allclose(float(ad), float(ac), rtol=1e-6)

    gd = jax.grad(lambda p: moe_apply(p, x, cfg_d)[0].sum())(p)
    gc = jax.grad(lambda p: moe_apply(p, x, cfg_c)[0].sum())(p)
    for k in gd:
        np.testing.assert_allclose(
            np.asarray(gd[k]), np.asarray(gc[k]), rtol=1e-4, atol=1e-4
        )


def test_dropless_moe_decode_shape():
    """S == 1 decode (single global group) through the dropless path."""
    from repro.models.moe import moe_apply, moe_init

    cfg_d, cfg_c = _moe_cfg(True), _moe_cfg(False)
    p = moe_init(jax.random.PRNGKey(0), cfg_d)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 1, 16))
    yd, _ = jax.jit(lambda p, x: moe_apply(p, x, cfg_d))(p, x)
    yc, _ = moe_apply(p, x, cfg_c)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yc), **TOL)


def test_dropless_moe_empty_experts():
    """A router biased so some experts receive zero tokens: their FFN
    blocks are absent from the topology and contribute nothing."""
    from repro.models.moe import moe_apply, moe_init

    cfg_d, cfg_c = _moe_cfg(True), _moe_cfg(False)
    p = dict(moe_init(jax.random.PRNGKey(0), cfg_d))
    # route everything to experts {0, 1}: experts 2 and 3 stay empty
    router = np.zeros((16, 4), np.float32)
    router[:, 2:] = -1e9
    p["router"] = jnp.asarray(router)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 12, 16))
    yd, _ = moe_apply(p, x, cfg_d)
    yc, _ = moe_apply(p, x, cfg_c)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yc), **TOL)
