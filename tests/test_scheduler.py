"""Continuous-batching scheduler: equivalence, invariants, plan-warm admission.

The core contract: N concurrent requests of mixed lengths decoded by the
continuous-batching scheduler produce tokens IDENTICAL (and logits within
1e-6) to N independent single-sequence ``ServeEngine.generate`` runs — for
greedy and sampled decoding, through forced eviction/resume, and with a
1-D device mesh attached.  Scheduling itself is exercised with seeded fake
clocks: deterministic transcripts, capacity invariants every step, no
starvation under either admission policy.
"""
import dataclasses
import itertools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.cache import PlanCache
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serve.engine import ServeEngine
from repro.sparse import random_pattern

from test_distributed import run_with_devices


@pytest.fixture(scope="module")
def engine():
    """f32 reduced llama engine — the single-sequence numeric reference."""
    cfg = get_config("llama3.2-3b", reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, max_len=20)


def _prompts(cfg, lengths, seed=7):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
        for n in lengths
    ]


def _reference(engine, prompt, max_new, temperature=0.0, rng=None):
    out, _ = engine.generate(
        jnp.asarray(prompt)[None], max_new, temperature=temperature, rng=rng
    )
    return np.asarray(out)[0]


def _fake_clock(step=0.5):
    counter = itertools.count()
    return lambda: next(counter) * step


# ---------------------------------------------------------------------- #
# token + logit equivalence vs N independent generate() runs
# ---------------------------------------------------------------------- #
def test_greedy_matches_independent_generate_with_logits(engine):
    cfg = engine.cfg
    prompts = _prompts(cfg, [3, 7, 5])
    gens = [6, 4, 8]
    reqs = [
        {"prompt": p, "max_new_tokens": g, "rid": f"r{i}"}
        for i, (p, g) in enumerate(zip(prompts, gens))
    ]
    results, sched = engine.serve(
        reqs, page_size=4, max_batch=3, record_logits=True
    )
    for i, (p, g) in enumerate(zip(prompts, gens)):
        ref = _reference(engine, p, g)
        np.testing.assert_array_equal(results[f"r{i}"]["tokens"], ref)
        # logits within 1e-6 of the single-sequence path, step by step
        P = len(p)
        cache = init_cache(cfg, 1, P + g)
        logits, cache = prefill(engine.params, cfg, jnp.asarray(p)[None], cache)
        rows = [np.asarray(logits[:, -1].astype(jnp.float32))[0]]
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None].astype(
            jnp.int32
        )
        for j in range(g - 1):
            lg, cache = decode_step(
                engine.params, cfg, nxt, cache, jnp.int32(P + j)
            )
            row = lg[:, 0].astype(jnp.float32)
            rows.append(np.asarray(row)[0])
            nxt = jnp.argmax(row, -1)[:, None].astype(jnp.int32)
        got = sched.requests[f"r{i}"].logits
        assert len(got) == len(rows) == g
        for a, b in zip(got, rows):
            np.testing.assert_allclose(a, b, atol=1e-6, rtol=0)


def test_sampling_matches_independent_generate(engine):
    cfg = engine.cfg
    prompts = _prompts(cfg, [4, 6, 3], seed=11)
    reqs = [
        {
            "prompt": p,
            "max_new_tokens": 6,
            "temperature": 0.8,
            "rng": jax.random.PRNGKey(100 + i),
            "rid": f"s{i}",
        }
        for i, p in enumerate(prompts)
    ]
    results, _ = engine.serve(reqs, page_size=4, max_batch=3)
    for i, p in enumerate(prompts):
        ref = _reference(
            engine, p, 6, temperature=0.8, rng=jax.random.PRNGKey(100 + i)
        )
        np.testing.assert_array_equal(results[f"s{i}"]["tokens"], ref)


def test_eviction_and_resume_are_lossless(engine):
    """Page pressure forces mid-decode eviction; the preempted sequence
    resumes bit-for-bit, so final tokens still match independent runs."""
    cfg = engine.cfg
    prompts = _prompts(cfg, [6, 6, 6], seed=23)
    reqs = [
        {"prompt": p, "max_new_tokens": 8, "rid": f"e{i}"}
        for i, p in enumerate(prompts)
    ]
    # 3 lanes x final length 13 = 4 pages each (12 total) but only 9 pages
    results, sched = engine.serve(
        reqs, page_size=4, max_batch=3, num_pages=9
    )
    assert sched.stats["evictions"] > 0, "test must exercise eviction"
    assert sched.stats["resumes"] > 0
    for i, p in enumerate(prompts):
        ref = _reference(engine, p, 8)
        np.testing.assert_array_equal(results[f"e{i}"]["tokens"], ref)
        assert results[f"e{i}"]["state"] == "FINISHED"


def test_more_requests_than_lanes_all_finish_fcfs(engine):
    cfg = engine.cfg
    prompts = _prompts(cfg, [3, 5, 4, 6, 2, 4], seed=31)
    reqs = [
        {"prompt": p, "max_new_tokens": 3 + (i % 3), "rid": f"q{i}"}
        for i, p in enumerate(prompts)
    ]
    results, sched = engine.serve(reqs, page_size=4, max_batch=2)
    assert sched.stats["finished"] == len(reqs)
    for i, p in enumerate(prompts):
        ref = _reference(engine, p, 3 + (i % 3))
        np.testing.assert_array_equal(results[f"q{i}"]["tokens"], ref)


# ---------------------------------------------------------------------- #
# event-driven simulation: fake clock, invariants every step
# ---------------------------------------------------------------------- #
def test_step_invariants_under_fake_clock(engine):
    cfg = engine.cfg
    sched = engine.make_scheduler(
        page_size=4, max_batch=2, num_pages=8, clock=_fake_clock()
    )
    for i, p in enumerate(_prompts(cfg, [5, 3, 6, 4], seed=41)):
        sched.submit(p, max_new_tokens=5, rid=f"c{i}", arrival=float(i))
    kv = sched.kv
    seen_running = set()
    while sched.pending():
        ev = sched.step()
        # capacity never exceeded, allocator never leaks or double-books
        kv.allocator.check()
        assert kv.allocator.num_held <= kv.allocator.num_pages
        assert sum(r is not None for r in sched.lanes) <= sched.max_batch
        assert len(ev["running"]) <= sched.max_batch
        held = sum(len(t) for t in kv.page_table.values())
        assert held == kv.allocator.num_held
        seen_running.update(ev["running"])
        assert sched.stats["steps"] < 500
    assert seen_running == {f"c{i}" for i in range(4)}  # no starvation
    assert kv.allocator.num_free == kv.allocator.num_pages  # all released
    for i in range(4):
        m = sched.requests[f"c{i}"].metrics
        # timestamps come from the fake clock and are ordered
        assert 0 <= m["admitted_at"] <= m["finished_at"]
        assert m["first_token_at"] <= m["finished_at"]


def test_transcript_is_deterministic_in_lengths_only(engine):
    """Admission/eviction/page tables depend only on integer lengths and
    arrival order — never token values — so two runs over different
    prompts of the same lengths yield identical transcripts (the property
    the golden serving fixture freezes)."""
    cfg = engine.cfg
    lengths, gens = [6, 6, 5], [6, 5, 6]

    def transcript(seed):
        sched = engine.make_scheduler(
            page_size=4, max_batch=2, num_pages=7, clock=_fake_clock()
        )
        for i, p in enumerate(_prompts(cfg, lengths, seed=seed)):
            sched.submit(p, max_new_tokens=gens[i], rid=f"t{i}", arrival=float(i))
        sched.run()
        return sched.transcript

    assert transcript(seed=1) == transcript(seed=2)


# ---------------------------------------------------------------------- #
# plan-warm admission
# ---------------------------------------------------------------------- #
def test_cold_plans_staged_once_then_warm_restart_stages_zero(engine, tmp_path):
    """Cold patterns are staged off the decode path (bounded per step); a
    restarted scheduler over the same persistent cache stages ZERO."""
    cfg = engine.cfg
    store = PlanCache(str(tmp_path))
    pats = tuple(
        random_pattern(64, 64, 16, 16, 0.4, seed=s) for s in (0, 1)
    )
    prompts = _prompts(cfg, [4, 5], seed=51)

    def serve_once():
        sched = engine.make_scheduler(
            page_size=4, max_batch=2, plan_cache=store,
            cold_stage_budget=1, clock=_fake_clock(),
        )
        for i, p in enumerate(prompts):
            sched.submit(
                p, max_new_tokens=4, patterns=pats, rid=f"p{i}",
                arrival=float(i),
            )
        results = sched.run()
        return results, sched

    results, sched = serve_once()
    assert sched.stats["plans_staged"] >= len(pats)
    assert all(r["state"] == "FINISHED" for r in results.values())
    staged_events = [ev for ev in sched.transcript if ev["staged"]]
    assert all(len(ev["staged"]) <= 1 for ev in staged_events)  # budget
    # "restart": a fresh scheduler over the same on-disk plan cache
    results2, sched2 = serve_once()
    assert sched2.stats["plans_staged"] == 0, "warm restart must not re-stage"
    np.testing.assert_array_equal(
        results["p0"]["tokens"], results2["p0"]["tokens"]
    )


def test_warm_first_policy_reorders_but_never_starves(engine, tmp_path):
    """warm_first admits plan-warm requests ahead of cold ones; aging
    (max_skips) guarantees the cold head still runs."""
    cfg = engine.cfg
    store = PlanCache(str(tmp_path))
    cold_pat = (random_pattern(64, 64, 16, 16, 0.4, seed=9),)
    prompts = _prompts(cfg, [4, 4, 4], seed=61)
    sched = engine.make_scheduler(
        page_size=4, max_batch=1, plan_cache=store, policy="warm_first",
        cold_stage_budget=0,  # never stage: the cold request stays cold
        max_skips=2, clock=_fake_clock(),
    )
    sched.submit(prompts[0], 4, patterns=cold_pat, rid="cold", arrival=0.0)
    sched.submit(prompts[1], 4, rid="warm1", arrival=1.0)
    sched.submit(prompts[2], 4, rid="warm2", arrival=2.0)
    results = sched.run()
    assert all(r["state"] == "FINISHED" for r in results.values())
    assert sched.stats["plans_staged"] == 0
    m = {rid: sched.requests[rid].metrics["admitted_at"] for rid in results}
    # a later-arriving warm request was admitted before the cold head...
    assert m["warm1"] < m["cold"]
    # ...and tokens are still exactly the single-sequence reference
    np.testing.assert_array_equal(
        results["cold"]["tokens"], _reference(engine, prompts[0], 4)
    )


def test_warm_first_without_aging_would_not_default(engine):
    with pytest.raises(ValueError):
        engine.make_scheduler(policy="best_effort")


def _seed_linear_corpus(store, n=10):
    """Measured `linear` plans with planted timings proportional to tile
    count, so the cost model ranks patterns by n_tiles."""
    from repro.core import cost_model as cmlib
    from repro.core.cache import TuningPlan, plan_key
    from repro.core.staging import StagingOptions
    from repro.sparse.linear import pattern_hash

    for i in range(n):
        p = random_pattern(64, 64, 8, 8, 0.15 + 0.08 * i, seed=300 + i)
        feats = cmlib.pattern_features(p)
        store.store_plan(
            plan_key("linear", pattern_hash(p), "cpu"),
            TuningPlan(
                kind="linear", structure_hash=pattern_hash(p),
                options=StagingOptions(backend="grouped", tile=(8, 8)),
                device="cpu",
                timings={"grouped": float(np.exp(-10 + 0.9 * feats[2]))},
                meta={"d_in": p.d_in, "d_out": p.d_out, "tm": p.tm,
                      "tk": p.tk, "n_tiles": p.n_tiles,
                      "density": p.density},
                source="measured",
            ),
        )


def test_cold_cost_scoring_admits_cheapest_staging_first(engine, tmp_path):
    """With cold_cost_scoring, an all-cold queue admits the request whose
    patterns the model predicts cheapest to stage — not arrival order."""
    cfg = engine.cfg
    store = PlanCache(str(tmp_path))
    _seed_linear_corpus(store)
    expensive = (random_pattern(64, 64, 8, 8, 0.85, seed=401),)
    cheap = (random_pattern(64, 64, 8, 8, 0.2, seed=402),)
    prompts = _prompts(cfg, [4, 4], seed=71)
    sched = engine.make_scheduler(
        page_size=4, max_batch=1, plan_cache=store, policy="warm_first",
        cold_cost_scoring=True, cold_stage_budget=0, max_skips=10,
        clock=_fake_clock(),
    )
    sched.submit(prompts[0], 4, patterns=expensive, rid="slow", arrival=0.0)
    sched.submit(prompts[1], 4, patterns=cheap, rid="fast", arrival=1.0)
    results = sched.run()
    assert all(r["state"] == "FINISHED" for r in results.values())
    m = {rid: sched.requests[rid].metrics["admitted_at"] for rid in results}
    assert m["fast"] < m["slow"]  # later arrival, cheaper predicted staging
    np.testing.assert_array_equal(
        results["slow"]["tokens"], _reference(engine, prompts[0], 4)
    )


def test_cold_cost_scoring_off_keeps_arrival_order(engine, tmp_path):
    """Default (scoring off): the same all-cold queue admits in arrival
    order — the golden-transcript behavior."""
    cfg = engine.cfg
    store = PlanCache(str(tmp_path))
    _seed_linear_corpus(store)
    expensive = (random_pattern(64, 64, 8, 8, 0.85, seed=401),)
    cheap = (random_pattern(64, 64, 8, 8, 0.2, seed=402),)
    prompts = _prompts(cfg, [4, 4], seed=71)
    sched = engine.make_scheduler(
        page_size=4, max_batch=1, plan_cache=store, policy="warm_first",
        cold_stage_budget=0, max_skips=10, clock=_fake_clock(),
    )
    sched.submit(prompts[0], 4, patterns=expensive, rid="slow", arrival=0.0)
    sched.submit(prompts[1], 4, patterns=cheap, rid="fast", arrival=1.0)
    results = sched.run()
    m = {rid: sched.requests[rid].metrics["admitted_at"] for rid in results}
    assert m["slow"] < m["fast"]


# ---------------------------------------------------------------------- #
# 1-D mesh path: scheduler composes with sharded staging
# ---------------------------------------------------------------------- #
def test_mesh_scheduler_matches_generate_and_warms_shard_plans():
    run_with_devices("""
        import dataclasses, tempfile
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.core.cache import PlanCache
        from repro.launch.mesh import make_staging_mesh
        from repro.models import init_params
        from repro.serve.engine import ServeEngine
        from repro.sparse import random_pattern

        cfg = get_config("llama3.2-3b", reduced=True)
        cfg = dataclasses.replace(
            cfg, compute_dtype="float32", param_dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        mesh = make_staging_mesh(2)
        eng = ServeEngine(cfg, params, max_len=20, mesh=mesh)

        rng = np.random.default_rng(3)
        prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
                   for n in (3, 6, 4)]
        store = PlanCache(tempfile.mkdtemp())
        pat = (random_pattern(64, 64, 16, 16, 0.4, seed=2),)
        reqs = [{"prompt": p, "max_new_tokens": 5, "rid": f"m{i}",
                 "patterns": pat, "arrival": float(i)}
                for i, p in enumerate(prompts)]
        results, sched = eng.serve(
            reqs, page_size=4, max_batch=2, plan_cache=store)
        assert sched.mesh is mesh
        # base plan + one per shard of the 1-D mesh were staged at admission
        assert sched.stats["plans_staged"] >= 3, sched.stats
        for i, p in enumerate(prompts):
            out, _ = eng.generate(jnp.asarray(p)[None], 5)
            np.testing.assert_array_equal(
                results[f"m{i}"]["tokens"], np.asarray(out)[0])
        print("MESH-EQ-OK")
    """, n=2)


# ---------------------------------------------------------------------- #
# prefix sharing + chunked prefill
# ---------------------------------------------------------------------- #
def test_prefix_sharing_allocates_prefix_once_and_matches_generate(engine):
    """N requests with a common 12-token prefix must pay its pages and
    prefill FLOPs once (exactly 3 pages x 3 followers fewer allocations,
    ~1/N of the shared-span work) while decode stays token-identical and
    logits stay within 1e-6 of independent generate runs."""
    cfg = engine.cfg
    rng = np.random.default_rng(5)
    shared = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    suffixes = [2, 3, 4, 2]
    prompts = [
        np.concatenate([shared, rng.integers(0, cfg.vocab_size, k).astype(np.int32)])
        for k in suffixes
    ]
    reqs = [
        {"prompt": p, "max_new_tokens": 4, "rid": f"p{i}"}
        for i, p in enumerate(prompts)
    ]
    kw = dict(page_size=4, max_batch=4, record_logits=True)
    res_off, sched_off = engine.serve(reqs, **kw)
    res_on, sched_on = engine.serve(reqs, prefix_sharing=True, **kw)

    # footprint: page_size=4 -> the 12-token prefix is 3 pages, shared by
    # the 3 followers instead of re-allocated: exactly 9 pages saved
    assert sched_on.stats["prefix_hits"] == 3
    assert sched_on.stats["pages_shared"] == 9
    assert (
        sched_on.kv.allocator.total_allocated
        == sched_off.kv.allocator.total_allocated - 9
    )
    # prefill FLOPs for the shared span are skipped (12 tokens x 3)
    assert sched_on.stats["prefill_tokens"] == sched_off.stats["prefill_tokens"] - 36
    # the engine surfaces the counters
    assert engine.warmup_stats["prefix_hits"] == 3
    assert engine.warmup_stats["pages_shared"] == 9
    assert engine.warmup_stats["cow_copies"] == sched_on.stats["cow_copies"]

    for i, p in enumerate(prompts):
        ref = _reference(engine, p, 4)
        np.testing.assert_array_equal(res_on[f"p{i}"]["tokens"], ref)
        np.testing.assert_array_equal(res_off[f"p{i}"]["tokens"], ref)
        # per-step logits: sharing must stay within the 1e-6 contract
        got = sched_on.requests[f"p{i}"].logits
        want = sched_off.requests[f"p{i}"].logits
        assert len(got) == len(want) == 4
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, atol=1e-6, rtol=0)


def test_chunked_prefill_interleaves_with_decode_and_matches(engine):
    """A long prompt prefills in page-sized chunks across steps while the
    short requests keep decoding — and every output still matches the
    independent single-sequence reference."""
    cfg = engine.cfg
    prompts = _prompts(cfg, [14, 3, 4], seed=13)
    reqs = [
        {"prompt": p, "max_new_tokens": 5, "rid": f"c{i}", "arrival": float(i)}
        for i, p in enumerate(prompts)
    ]
    results, sched = engine.serve(
        reqs, page_size=4, max_batch=3, chunked_prefill=True, prefill_chunk=4,
        clock=_fake_clock(),
    )
    assert sched.stats["prefill_chunks"] >= 4 + 1 + 1  # 14/4 chunks + 2 shorts
    assert sched.stats["prefill_tokens"] == 14 + 3 + 4
    # interleaving: some step advanced the long prefill WHILE lanes decoded
    assert any(
        ev.get("prefill") and ev["running"] for ev in sched.transcript
    ), "chunked prefill never overlapped decode"
    for i, p in enumerate(prompts):
        ref = _reference(engine, p, 5)
        np.testing.assert_array_equal(results[f"c{i}"]["tokens"], ref)


def test_sharing_and_chunking_compose_under_page_pressure(engine):
    """Both features on with a pool small enough to force eviction: shared
    pages survive parking under their refcount, late chunk attachment picks
    up pages registered after admission, and everything stays lossless."""
    cfg = engine.cfg
    rng = np.random.default_rng(17)
    shared = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    prompts = [
        np.concatenate([shared, rng.integers(0, cfg.vocab_size, k).astype(np.int32)])
        for k in (2, 3, 2, 4)
    ]
    reqs = [
        {"prompt": p, "max_new_tokens": 4, "rid": f"g{i}", "arrival": float(i)}
        for i, p in enumerate(prompts)
    ]
    reqs[1]["temperature"] = 0.8
    reqs[1]["rng"] = jax.random.PRNGKey(321)
    results, sched = engine.serve(
        reqs, page_size=4, max_batch=4, num_pages=11,
        prefix_sharing=True, chunked_prefill=True, prefill_chunk=8,
        clock=_fake_clock(),
    )
    assert sched.stats["prefix_hits"] >= 1
    assert sched.stats["pages_shared"] >= 3
    for i, p in enumerate(prompts):
        ref = _reference(
            engine, p, 4,
            temperature=reqs[i].get("temperature", 0.0),
            rng=jax.random.PRNGKey(321) if i == 1 else None,
        )
        np.testing.assert_array_equal(results[f"g{i}"]["tokens"], ref)
    assert sched.kv.allocator.num_free == sched.kv.allocator.num_pages


def test_pages_exhausted_mid_decode_evicts_and_resumes_lossless(engine, monkeypatch):
    """A typed PagesExhausted raised mid-append (the COW/growth path) must
    evict per policy and keep every sequence lossless — including the
    victim whose step was dropped before its append (rng rewind)."""
    from repro.serve.paged_cache import PagesExhausted

    cfg = engine.cfg
    prompts = _prompts(cfg, [5, 6], seed=77)
    sched = engine.make_scheduler(
        page_size=4, max_batch=2, num_pages=10, clock=_fake_clock()
    )
    for i, p in enumerate(prompts):
        sched.submit(
            p, max_new_tokens=6, temperature=0.7,
            rng=jax.random.PRNGKey(500 + i), rid=f"x{i}", arrival=float(i),
        )
    real = sched.kv.append_token
    fired = {}
    def flaky(rid, slices, position):
        if rid == "x0" and position >= 7 and "x0" not in fired:
            fired["x0"] = True
            raise PagesExhausted("forced mid-decode exhaustion")
        return real(rid, slices, position)
    monkeypatch.setattr(sched.kv, "append_token", flaky)
    results = sched.run()
    assert fired, "the forced exhaustion never triggered"
    assert sched.stats["evictions"] >= 1
    for i, p in enumerate(prompts):
        ref = _reference(
            engine, p, 6, temperature=0.7, rng=jax.random.PRNGKey(500 + i)
        )
        np.testing.assert_array_equal(results[f"x{i}"]["tokens"], ref)

    # single lane: nothing to evict -> the lane parks ITSELF, rewinds its
    # rng split, and redoes the step after resume with identical sampling
    sched2 = engine.make_scheduler(
        page_size=4, max_batch=1, num_pages=8, clock=_fake_clock()
    )
    sched2.submit(
        prompts[0], max_new_tokens=6, temperature=0.7,
        rng=jax.random.PRNGKey(500), rid="solo",
    )
    real2 = sched2.kv.append_token
    fired2 = {}
    def flaky2(rid, slices, position):
        if position >= 7 and not fired2:
            fired2["solo"] = True
            raise PagesExhausted("forced self-park")
        return real2(rid, slices, position)
    monkeypatch.setattr(sched2.kv, "append_token", flaky2)
    results2 = sched2.run()
    assert fired2 and sched2.stats["evictions"] >= 1
    ref = _reference(
        engine, prompts[0], 6, temperature=0.7, rng=jax.random.PRNGKey(500)
    )
    np.testing.assert_array_equal(results2["solo"]["tokens"], ref)


def test_sharing_and_chunking_require_fully_paged_cache():
    """SSM/conv state summarizes the whole prefix: it can be neither
    inherited from shared pages nor rebuilt chunk-by-chunk, so the knobs
    must be rejected loudly for state-carrying models."""
    from repro.serve.scheduler import ContinuousBatchingScheduler

    cfg = get_config("mamba2-1.3b", reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    for kw in ({"prefix_sharing": True}, {"chunked_prefill": True}):
        with pytest.raises(ValueError, match="fully-paged"):
            ContinuousBatchingScheduler(cfg, params, max_len=16, **kw)
    ContinuousBatchingScheduler(cfg, params, max_len=16)  # defaults stay fine
