"""Golden-fixture regression tests.

Three small serialized structures (banded, arrow, random-block — see
tests/fixtures/make_fixtures.py) with frozen expected outputs, structure
hashes, and plan JSON.  A change to the structure-hash function, the VBR
field layout, the plan schema, or the partitioner's numerical behaviour
fails HERE loudly — instead of silently orphaning every persisted cache
entry in the field.  Regenerate intentionally with::

    PYTHONPATH=src python tests/fixtures/make_fixtures.py
"""
import json
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import vbr as vbrlib
from repro.core.cache import PlanCache, TuningPlan, plan_key
from repro.core.staging import StagingOptions, clear_cache, stage_spmm, stage_spmv
from repro.distributed.partition import (
    load_shard_plan,
    make_shard_plan,
    save_shard_plan,
)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
NAMES = ["banded", "arrow", "random_block"]
_STRUCTURE_FIELDS = ("rpntr", "cpntr", "bindx", "bpntrb", "bpntre", "indx")


@pytest.fixture(autouse=True)
def _fresh(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    clear_cache()
    yield
    clear_cache()


def load_fixture(name):
    with np.load(os.path.join(FIXTURES, f"{name}.npz")) as z:
        fields = {f: z[f] for f in _STRUCTURE_FIELDS}
        v = vbrlib.VBR(
            shape=tuple(int(d) for d in z["shape"]), val=z["val"], **fields
        )
        data = {k: z[k] for k in ("x", "X", "y_spmv", "y_spmm")}
        return v, data, str(z["structure_hash"])


@pytest.mark.parametrize("name", NAMES)
def test_structure_hash_is_stable(name):
    """The persisted-cache key must not drift: a hash change orphans every
    plan and structure file ever written."""
    v, _, frozen_hash = load_fixture(name)
    assert vbrlib.structure_hash(v) == frozen_hash


@pytest.mark.parametrize("name", NAMES)
@pytest.mark.parametrize("backend", ["unrolled", "grouped", "bucketed"])
def test_golden_spmv_spmm(name, backend):
    v, data, _ = load_fixture(name)
    val = jnp.asarray(v.val)
    got_v = np.asarray(
        stage_spmv(v, StagingOptions(backend=backend))(val, jnp.asarray(data["x"]))
    )
    np.testing.assert_allclose(got_v, data["y_spmv"], atol=3e-5, rtol=3e-5)
    got_m = np.asarray(
        stage_spmm(v, data["X"].shape[1], StagingOptions(backend=backend))(
            val, jnp.asarray(data["X"])
        )
    )
    np.testing.assert_allclose(got_m, data["y_spmm"], atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("name", NAMES)
def test_golden_sharded_matches_frozen(name):
    """The partitioner (any strategy) must still reproduce the frozen
    outputs through the sharded host path."""
    v, data, _ = load_fixture(name)
    for strategy in ("lpt", "contiguous"):
        got = np.asarray(
            stage_spmv(v, shards=4, shard_strategy=strategy)(
                jnp.asarray(v.val), jnp.asarray(data["x"])
            )
        )
        np.testing.assert_allclose(
            got, data["y_spmv"], atol=3e-5, rtol=3e-5, err_msg=strategy
        )


@pytest.mark.parametrize("name", NAMES)
def test_plan_json_schema_roundtrip(name):
    """The frozen plan JSON must parse, round-trip bit-identically, and
    store/load through PlanCache unchanged — schema drift fails here."""
    with open(os.path.join(FIXTURES, f"{name}_plan.json")) as f:
        doc = json.load(f)
    plan = TuningPlan.from_dict(doc)
    assert plan.to_dict() == doc
    cache = PlanCache(os.environ["REPRO_CACHE_DIR"])
    key = plan_key(plan.kind, plan.structure_hash, plan.device)
    cache.store_plan(key, plan)
    back = cache.load_plan(key)
    assert back is not None and back.to_dict() == doc


@pytest.mark.parametrize("name", NAMES)
def test_structure_cache_roundtrip(name):
    """Fixture structures survive the persistent structure cache and come
    back under the same (frozen) hash."""
    v, _, frozen_hash = load_fixture(name)
    cache = PlanCache(os.environ["REPRO_CACHE_DIR"])
    cache.store_structure(v)
    back = cache.load_structure(frozen_hash)
    assert back is not None
    for f in _STRUCTURE_FIELDS:
        np.testing.assert_array_equal(getattr(back, f), getattr(v, f))
    assert back.shape == v.shape


def _make_fixtures_module():
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "make_fixtures", os.path.join(FIXTURES, "make_fixtures.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def reblock_fixture():
    with open(os.path.join(FIXTURES, "reblock_plan.json")) as f:
        return json.load(f)


def test_golden_reblock_spec_is_stable(reblock_fixture):
    """The reblocking DP is part of the persisted-plan contract: a drift
    in the Ahrens–Boman cost function, the DP's tie-breaking, or the
    ``ReblockSpec`` schema would orphan (or worse, silently mis-apply)
    every cached reblocked plan — so the proposal for the misblocked band
    is frozen bit-for-bit."""
    from repro.core import reblock as rblib

    v = _make_fixtures_module().misblocked_banded()
    assert vbrlib.structure_hash(v) == reblock_fixture["structure_hash"]
    spec = rblib.propose_reblockings(v, device="cpu")[0]
    assert spec.to_dict() == reblock_fixture["reblock"]


def test_golden_reblock_plan_roundtrip(reblock_fixture):
    """A plan carrying a ``reblock`` spec must round-trip the JSON schema
    and the PlanCache bit-identically, and the spec must re-apply onto
    the source structure (hash-validated inside ``apply_reblock``)."""
    from repro.core import reblock as rblib

    doc = reblock_fixture["plan"]
    plan = TuningPlan.from_dict(doc)
    assert plan.to_dict() == doc
    assert plan.reblock is not None
    cache = PlanCache(os.environ["REPRO_CACHE_DIR"])
    key = plan_key(plan.kind, plan.structure_hash, plan.device, reblock=True)
    cache.store_plan(key, plan)
    back = cache.load_plan(key)
    assert back is not None and back.to_dict() == doc
    v = _make_fixtures_module().misblocked_banded()
    spec = rblib.ReblockSpec.from_dict(plan.reblock)
    rvbr, _ = rblib.apply_reblock(v, spec)
    np.testing.assert_allclose(rvbr.to_dense(), v.to_dense())


def test_golden_reblock_key_segment_is_stable(reblock_fixture):
    """Extended-candidate-space plans live under the ``-rb`` key segment;
    base-space keys must stay byte-identical to pre-reblocking releases."""
    h = reblock_fixture["structure_hash"]
    assert plan_key("spmv", h, "cpu") == f"spmv-{h}-cpu"
    assert plan_key("spmv", h, "cpu", reblock=True) == f"spmv-{h}-cpu-rb"


@pytest.fixture(scope="module")
def serving_fixture():
    with open(os.path.join(FIXTURES, "serving.json")) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def serving_replay(serving_fixture):
    """Replay the frozen 3-request serve under a fake clock once."""
    import dataclasses
    import itertools

    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.engine import ServeEngine
    from repro.serve.scheduler import ContinuousBatchingScheduler

    doc = serving_fixture
    cfg = get_config(doc["config"], reduced=True)
    cfg = dataclasses.replace(
        cfg, compute_dtype="float32", param_dtype="float32"
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    counter = itertools.count()
    sched = ContinuousBatchingScheduler(
        cfg, params, clock=lambda: float(next(counter)), **doc["scheduler"]
    )
    for r in doc["requests"]:
        sched.submit(
            np.asarray(r["prompt"], np.int32), r["max_new_tokens"],
            rid=r["rid"], arrival=r["arrival"],
        )
    results = sched.run()
    eng = ServeEngine(cfg, params, max_len=doc["scheduler"]["max_len"])
    return doc, sched, results, eng


def test_golden_serving_paged_cache_layout(serving_replay):
    """Arena shapes, leaf classification, and the reserved zero page are
    part of the persisted-serving contract — drift fails here, not in
    the field."""
    doc, sched, _, _ = serving_replay
    kv = sched.kv
    frozen = doc["paged_cache"]
    assert kv.view_pages == frozen["view_pages"]
    assert kv.zero_page == frozen["zero_page"]
    assert kv.num_leaves == frozen["num_leaves"]
    assert list(kv.paged) == frozen["paged"]
    got_shapes = [None if a is None else list(a.shape) for a in kv._arenas]
    assert got_shapes == frozen["arena_shapes"]


def test_golden_serving_transcript(serving_replay):
    """The continuous-batching schedule (admissions, the forced eviction
    and lossless resume, page tables per step) is integer-deterministic
    and frozen."""
    doc, sched, _, _ = serving_replay
    assert len(sched.transcript) == len(doc["transcript"])
    for got, want in zip(sched.transcript, doc["transcript"]):
        assert got == want
    for k, v in doc["stats"].items():
        assert sched.stats[k] == v, k
    assert doc["stats"]["evictions"] >= 1  # the fixture must exercise it


def test_golden_serving_tokens_match_frozen_and_single_sequence(serving_replay):
    """Batched continuous-batching decode is regression-pinned BOTH ways:
    against the frozen token ids and against a live single-sequence
    ``generate`` run per request."""
    import jax.numpy as jnp_

    doc, _, results, eng = serving_replay
    for r in doc["requests"]:
        rid = r["rid"]
        np.testing.assert_array_equal(
            results[rid]["tokens"], np.asarray(doc["tokens"][rid], np.int32)
        )
        ref, _ = eng.generate(
            jnp_.asarray(np.asarray(r["prompt"], np.int32))[None],
            r["max_new_tokens"],
        )
        np.testing.assert_array_equal(results[rid]["tokens"], np.asarray(ref)[0])


@pytest.mark.parametrize("name", NAMES)
def test_shard_plan_cache_roundtrip(name):
    """Partition records for the fixtures round-trip the plan cache and
    rebuild identical shards (spans, gathers, sub-hashes)."""
    v, _, _ = load_fixture(name)
    plan = make_shard_plan(v, 4, "lpt")
    cache = PlanCache(os.environ["REPRO_CACHE_DIR"])
    save_shard_plan(plan, cache)
    back = load_shard_plan(v, 4, "lpt", cache)
    assert back is not None
    assert back.shard_hashes() == plan.shard_hashes()
    for a, b in zip(plan.shards, back.shards):
        assert a.spans == b.spans
        np.testing.assert_array_equal(a.val_index, b.val_index)
        np.testing.assert_array_equal(a.row_index, b.row_index)
