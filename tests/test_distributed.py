"""Distribution: sharding specs, multi-device pjit (subprocess), elastic
restore across mesh shapes, HLO analyzer."""
import os
import subprocess
import sys
import textwrap

import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(script: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    # pin the child to CPU explicitly: the forced host devices are a CPU
    # feature, and leaving the platform unset makes jax PROBE for
    # accelerator plugins first — on an image with the TPU toolchain
    # installed that probe idles for minutes before falling back
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_param_specs_resolve():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.distributed.sharding import ParallelConfig, param_specs
    from repro.launch.specs import abstract_params

    cfg = get_config("deepseek-v2-236b", reduced=True)
    params = abstract_params(cfg)
    specs = param_specs(cfg, params)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    joined = {"/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp): s
              for kp, s in flat}
    # stacked group leaves get a leading None for the scan dim
    moe_w1 = [s for p, s in joined.items() if p.endswith("moe/w1")]
    assert moe_w1 and moe_w1[0][1] == "__M__"  # experts over tensor axis
    wq = [s for p, s in joined.items() if p.endswith("mixer/wuq")]
    # column-parallel: output dim jointly (fsdp, tensor)-sharded
    assert wq and wq[0][1] is None and wq[0][2] == "__FM__"


def test_pjit_train_step_multidevice_matches_single():
    """Same loss on a (2,2,2) mesh as on 1 device — SPMD correctness."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import init_params
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.train.step import make_train_step
        from repro.distributed.sharding import (ParallelConfig, param_specs,
            batch_specs, make_shardings)
        from repro.distributed.ctx import activation_sharding

        cfg = get_config("llama3-8b", reduced=True)
        import dataclasses
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        oc = AdamWConfig(lr=1e-3)
        pc = ParallelConfig(compress_grads=False)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params, oc)
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size)}
        step = make_train_step(cfg, oc, pc)

        # single device reference
        p1, o1, m1 = jax.jit(step)(params, opt, batch, jnp.int32(0))

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ps = make_shardings(mesh, pc, param_specs(cfg, params))
        os_ = {"mu": ps, "nu": ps, "count": NamedSharding(mesh, P())}
        bs = make_shardings(mesh, pc, batch_specs(cfg, batch))
        with activation_sharding(mesh, pc):
            jstep = jax.jit(step, in_shardings=(ps, os_, bs, NamedSharding(mesh, P())),
                            out_shardings=(ps, os_, None))
            pd = jax.device_put(params, ps)
            od = jax.device_put(opt, os_)
            bd = jax.device_put(batch, bs)
            p2, o2, m2 = jstep(pd, od, bd, jnp.int32(0))
        print("LOSS1", float(m1["loss"]))
        print("LOSS2", float(m2["loss"]))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
        # updated params agree
        l1 = np.asarray(jax.tree.leaves(p1)[0], np.float32)
        l2 = np.asarray(jax.device_get(jax.tree.leaves(p2)[0]), np.float32)
        np.testing.assert_allclose(l1, l2, rtol=1e-3, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_moe_ep_multidevice_matches_single():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import init_params, forward_train
        from repro.distributed.sharding import (ParallelConfig, param_specs,
            batch_specs, make_shardings)
        from repro.distributed.ctx import activation_sharding

        cfg = get_config("deepseek-v2-236b", reduced=True)
        cfg = dataclasses.replace(cfg, compute_dtype="float32",
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        params = init_params(cfg, jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size)}
        ref, _ = jax.jit(lambda p, b: forward_train(p, cfg, b))(params, batch)

        pc = ParallelConfig()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ps = make_shardings(mesh, pc, param_specs(cfg, params))
        bs = make_shardings(mesh, pc, batch_specs(cfg, batch))
        with activation_sharding(mesh, pc):
            f = jax.jit(lambda p, b: forward_train(p, cfg, b),
                        in_shardings=(ps, bs))
            got, _ = f(jax.device_put(params, ps), jax.device_put(batch, bs))
        np.testing.assert_allclose(np.asarray(jax.device_get(got), np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


def test_elastic_checkpoint_across_mesh_shapes(tmp_path):
    """Save sharded on a (4,2) mesh; restore onto (2,4) and 1-device."""
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import save_checkpoint, restore_checkpoint

        tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        sh_a = {{"w": NamedSharding(mesh_a, P("data", "model"))}}
        t_a = jax.device_put(tree, sh_a)
        save_checkpoint(r"{tmp_path}", 3, t_a)

        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        sh_b = {{"w": NamedSharding(mesh_b, P("model", "data"))}}
        t_b, step, _ = restore_checkpoint(r"{tmp_path}", tree, shardings=sh_b)
        assert step == 3
        assert t_b["w"].sharding == sh_b["w"]
        np.testing.assert_array_equal(np.asarray(jax.device_get(t_b["w"])),
                                      np.asarray(tree["w"]))
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_cells_on_small_mesh():
    """build_cell lowers+compiles train/prefill/decode for three families."""
    out = run_with_devices("""
        import jax, dataclasses
        from repro.configs import get_config, SHAPES
        from repro.launch.dryrun import build_cell
        from repro.distributed.sharding import ParallelConfig
        from repro.distributed.ctx import activation_sharding
        pc = ParallelConfig()
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        for arch in ["llama3-8b", "jamba-1.5-large-398b", "seamless-m4t-large-v2"]:
            cfg = get_config(arch, reduced=True)
            for shape_name in ["train_4k", "prefill_32k", "decode_32k"]:
                shape = dataclasses.replace(SHAPES[shape_name], seq_len=32,
                                            global_batch=8)
                with activation_sharding(mesh, pc):
                    jitted, args = build_cell(cfg, shape, mesh, pc)
                    jitted.lower(*args).compile()
                print("OK", arch, shape_name)
    """)
    assert out.count("OK") == 9


def test_hlo_analyzer_counts_scan_trips():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_stats import analyze_hlo
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        w = jax.ShapeDtypeStruct((4, 256, 256), jnp.bfloat16)
        x = jax.ShapeDtypeStruct((64, 256), jnp.bfloat16)
        def f(w, x):
            def body(x, wi):
                return jnp.tanh(x @ wi), None
            y, _ = jax.lax.scan(body, x, w)
            return y
        c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "data", "model")),
                                     NamedSharding(mesh, P(None, "model")))
                    ).lower(w, x).compile()
        r = analyze_hlo(c.as_text())
        expect = 2 * 64 * 256 * 256 * 4 / 8  # per-device, x4 layers
        assert abs(r["flops"] - expect) / expect < 0.05, r["flops"]
        ag = r["collectives"].get("all-gather", {"count": 0})
        assert ag["count"] == 4, ag  # one per scan iteration
        print("OK")
    """, n=8)
    assert "OK" in out


def test_sharded_staged_spmv_matches_single_on_8_devices():
    """Acceptance: on 8 forced host devices the shard_map-staged SpMV/SpMM
    match the single-device kernel within 1e-6 and the partitioner keeps
    the worst shard <= 1.5x the mean nnz."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import vbr as vbrlib
        from repro.core.staging import stage_spmv, stage_spmm
        from repro.launch.mesh import make_staging_mesh

        v = vbrlib.synthesize(360, 320, 20, 16, 80, block_sparsity=0.25,
                              uniform=False, seed=7)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(v.shape[1]).astype(np.float32))
        val = jnp.asarray(v.val)
        ref = stage_spmv(v)(val, x)

        mesh = make_staging_mesh(8)
        kern = stage_spmv(v, mesh=mesh)
        assert kern.imbalance() <= 1.5, kern.imbalance()
        got = jax.device_get(kern(val, x))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-6, rtol=1e-6)

        X = jnp.asarray(rng.standard_normal((v.shape[1], 8)).astype(np.float32))
        refm = stage_spmm(v, 8)(val, X)
        gotm = jax.device_get(stage_spmm(v, 8, mesh=mesh)(val, X))
        np.testing.assert_allclose(np.asarray(gotm), np.asarray(refm),
                                   atol=1e-6, rtol=1e-6)
        print("OK", float(kern.imbalance()))
    """)
    assert "OK" in out


def test_mesh2d_spmm_matches_1d_and_unsharded_with_warm_restart(tmp_path):
    """Acceptance (ISSUE 5): on 8 forced host devices, 2-D (shards x
    model) SpMM — overlapped-gather path enabled — matches the 1-D mesh
    and unsharded kernels within 1e-6, per-shard autotune plans are keyed
    with the model column count, and a warm restart re-stages with ZERO
    new plan files.  sparse_matmul_auto accepts the same 2-D mesh."""
    out = run_with_devices(f"""
        import os, numpy as np, jax, jax.numpy as jnp
        os.environ["REPRO_CACHE_DIR"] = r"{tmp_path}"
        from repro.core import vbr as vbrlib
        from repro.core.staging import StagingOptions, clear_cache, stage_spmm
        from repro.launch.mesh import make_staging_mesh

        v = vbrlib.synthesize(160, 140, 12, 10, 36, block_sparsity=0.25,
                              uniform=False, seed=7)
        rng = np.random.default_rng(0)
        X = jnp.asarray(rng.standard_normal((v.shape[1], 8)).astype(np.float32))
        val = jnp.asarray(v.val)
        ref = np.asarray(stage_spmm(v, 8)(val, X))

        ref1d = np.asarray(jax.device_get(
            stage_spmm(v, 8, mesh=make_staging_mesh(4))(val, X)))
        np.testing.assert_allclose(ref1d, ref, atol=1e-6, rtol=1e-6)

        # (2,4) with the default backend: pure 2-D equivalence
        kern24 = stage_spmm(v, 8, mesh=make_staging_mesh((2, 4)))
        assert kern24.overlap_gather and kern24.model_size == 4
        got24 = np.asarray(jax.device_get(kern24(val, X)))
        np.testing.assert_allclose(got24, ref, atol=1e-6, rtol=1e-6)
        np.testing.assert_allclose(got24, ref1d, atol=1e-6, rtol=1e-6)

        # (4,2) with autotune: per-shard plans keyed by model cols
        opts = StagingOptions(backend="autotune")
        mesh = make_staging_mesh((4, 2))
        kern = stage_spmm(v, 8, opts, mesh=mesh)  # overlap_gather on
        assert kern.overlap_gather and kern.model_size == 2
        got = np.asarray(jax.device_get(kern(val, X)))
        np.testing.assert_allclose(got, ref, atol=1e-6, rtol=1e-6)
        np.testing.assert_allclose(got, ref1d, atol=1e-6, rtol=1e-6)

        plans = os.path.join(r"{tmp_path}", "plans")
        names = set(os.listdir(plans))
        mc = [n for n in names if "-mc" in n]
        assert len(mc) == 4, mc  # one plan per shard, keyed by model cols

        # warm restart: fresh staging, zero new plan files
        clear_cache()
        kern = stage_spmm(v, 8, opts, mesh=make_staging_mesh((4, 2)))
        got = np.asarray(jax.device_get(kern(val, X)))
        np.testing.assert_allclose(got, ref, atol=1e-6, rtol=1e-6)
        assert set(os.listdir(plans)) == names, "warm restart re-benchmarked"

        # sparse_matmul_auto end-to-end on the same 2-D mesh
        from repro.sparse import linear
        pat = linear.random_pattern(64, 96, 8, 8, density=0.4)
        tiles = jnp.asarray(rng.standard_normal(
            (pat.n_tiles, 8, 8)).astype(np.float32))
        xs = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
        mesh = make_staging_mesh((2, 4))
        dense_ref = np.asarray(linear.sparse_matmul(xs, tiles, pat))
        got = np.asarray(jax.device_get(jax.jit(
            lambda a, t: linear.sparse_matmul_auto(
                a, t, pat, mesh=mesh, out_model=True))(xs, tiles)))
        np.testing.assert_allclose(got, dense_ref, atol=1e-5, rtol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_fetch_and_constrain_noop_outside_context():
    """Model code must run unchanged without an activation_sharding ctx."""
    import jax.numpy as jnp

    from repro.distributed.ctx import DP, MODEL, constrain, fetch

    x = jnp.ones((4, 8))
    assert constrain(x, DP, None) is x
    assert fetch(x, None, MODEL) is x
