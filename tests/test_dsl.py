"""The staged DSL: recording, partial evaluation, pattern matching."""
import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # keep deterministic cases running without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core.backends import match_block_matmul, run_reference, run_vectorized
from repro.core.dsl import (
    ArrayVal,
    ConcreteArrayVal,
    Const,
    Loop,
    RepRange,
    StagingError,
    Store,
    isDense,
    loopgen,
    stage_op,
)
from repro.core.ops_dsl import ArrayView, spmm_op, spmv_op


def test_loopgen_records_nest():
    def op(r1: RepRange, a: ArrayVal):
        def body(i):
            a[i] = r1.start + i

        return loopgen(r1, body)

    prog = stage_op(op, RepRange(3, 9), ArrayVal("a"))
    assert len(prog) == 1 and isinstance(prog[0], Loop)
    assert prog[0].start == 3 and prog[0].stop == 9
    (store,) = prog[0].body
    assert isinstance(store, Store) and not store.accumulate

    env = {"a": np.zeros(16)}
    run_reference(prog, env)
    np.testing.assert_array_equal(env["a"][3:9], np.arange(3, 9) + 3)


def test_accumulate_detection():
    y, x = ArrayVal("y"), ArrayVal("x")

    def op(r):
        loopgen(r, lambda i: y.__setitem__(i, y[i] + x[i]))

    prog = stage_op(op, RepRange(0, 4))
    assert prog[0].body[0].accumulate


def test_plain_range_unrolls():
    """Paper Listing 3: a plain range is fully unrolled at Stage 0."""
    a = ArrayVal("a")

    def op():
        loopgen(range(4), lambda i: a.__setitem__(i, i * 10))

    prog = stage_op(op)
    assert len(prog) == 4  # four independent stores, no Loop
    assert all(isinstance(s, Store) for s in prog)


def test_concrete_array_partial_eval_and_isdense():
    """isDense on ConcreteArrayVal elides zero work at staging time."""
    vals = np.array([1.0, 0.0, 3.0, 0.0])
    cv = ConcreteArrayVal("v", vals)
    y = ArrayVal("y")

    def op():
        for i in range(4):  # staging-time loop
            v = cv[i]
            if isDense(v):
                y[i] += v * 2

    prog = stage_op(op)
    assert len(prog) == 2  # stores for the two non-zeros only
    env = {"y": np.zeros(4)}
    run_reference(prog, env)
    np.testing.assert_array_equal(env["y"], [2, 0, 6, 0])


def test_nonaffine_index_rejected():
    a = ArrayVal("a")

    def op(r):
        loopgen(r, lambda i: a.__setitem__(i * i, 1.0))

    with pytest.raises(StagingError):
        stage_op(op, RepRange(0, 4))


def test_spmv_op_matches_block_matmul():
    prog = stage_op(
        spmv_op,
        RepRange(640, 690),
        RepRange(4175, 4235),
        ArrayView(ArrayVal("val"), 69722),  # Listing 2's constants
        ArrayVal("x"),
        ArrayVal("y"),
    )
    d = match_block_matmul(prog)
    assert d is not None
    assert (d.row_start, d.row_end) == (640, 690)
    assert (d.col_start, d.col_end) == (4175, 4235)
    assert d.val_off == 69722
    assert d.n_cols is None


def test_spmm_op_matches_block_matmul():
    prog = stage_op(
        spmm_op,
        RepRange(10, 20),
        RepRange(30, 45),
        RepRange(0, 512),
        ArrayView(ArrayVal("val"), 1000),
        ArrayVal("x"),
        ArrayVal("y"),
    )
    d = match_block_matmul(prog)
    assert d is not None
    assert d.n_cols == 512
    assert (d.row_start, d.col_start, d.val_off) == (10, 30, 1000)


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(1, 6),
    w=st.integers(1, 6),
    off=st.integers(0, 50),
    seed=st.integers(0, 100),
)
def test_reference_vs_vectorized_custom_op(h, w, off, seed):
    """An op OUTSIDE the matmul pattern: both backends must agree."""
    rng = np.random.default_rng(seed)
    val = rng.standard_normal(200).astype(np.float32)
    x = rng.standard_normal(64).astype(np.float32)

    def op(r1, r2, v, xs, ys):
        def body(i, j):
            ys[i] += v[(j - r2.start) * len(r1) + (i - r1.start)] + xs[j] * 2.0

        loopgen(r1, lambda i: loopgen(r2, lambda j: body(i, j)))

    prog = stage_op(
        op, RepRange(2, 2 + h), RepRange(5, 5 + w),
        ArrayView(ArrayVal("val"), off), ArrayVal("x"), ArrayVal("y"),
    )
    assert match_block_matmul(prog) is None  # not a matmul
    env_ref = {"val": val.copy(), "x": x.copy(), "y": np.zeros(32, np.float32)}
    run_reference(prog, env_ref)
    env_vec = {
        "val": jnp.asarray(val), "x": jnp.asarray(x),
        "y": jnp.zeros(32, jnp.float32),
    }
    env_vec = run_vectorized(prog, env_vec)
    np.testing.assert_allclose(np.asarray(env_vec["y"]), env_ref["y"], rtol=1e-5)
