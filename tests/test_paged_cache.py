"""Property-based tests for the paged KV cache (serve/paged_cache.py).

Random alloc/append/free/evict/resume interleavings must never leak or
double-allocate pages, and every page-table read must equal a dense
reference cache maintained in parallel BIT-FOR-BIT — the contract that
makes continuous-batching decode token-identical to the single-sequence
path.  Runs under real hypothesis when installed, else the deterministic
fixed-seed sampler in ``_hypothesis_stub``.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # keep deterministic sampling without hypothesis
    from _hypothesis_stub import given, settings, st

import jax

from repro.configs import get_config
from repro.models.transformer import init_cache
from repro.serve.paged_cache import PageAllocator, PagedKVCache, PagesExhausted


# ---------------------------------------------------------------------- #
# allocator
# ---------------------------------------------------------------------- #
@settings(max_examples=20)
@given(seed=st.integers(0, 10_000), num_pages=st.integers(1, 24))
def test_allocator_never_leaks_or_double_allocates(seed, num_pages):
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(num_pages)
    held = []  # list of page lists we own
    for _ in range(60):
        alloc.check()
        if held and rng.random() < 0.4:
            pages = held.pop(int(rng.integers(len(held))))
            alloc.free(pages)
        else:
            n = int(rng.integers(0, num_pages + 2))
            got = alloc.alloc(n)
            if n > alloc.num_free + (0 if got is None else n):
                assert got is None
            if got is None:
                continue
            assert len(got) == n
            held.append(got)
        # no page is owned twice
        flat = [p for ps in held for p in ps]
        assert len(flat) == len(set(flat))
        assert alloc.num_held == len(flat)
    for ps in held:
        alloc.free(ps)
    assert alloc.num_free == num_pages
    alloc.check()


def test_allocator_rejects_double_free_and_oversize():
    a = PageAllocator(4)
    got = a.alloc(3)
    assert a.alloc(2) is None and a.num_free == 1  # atomic: nothing taken
    a.free(got)
    with pytest.raises(ValueError):
        a.free(got[:1])
    a.check()


# ---------------------------------------------------------------------- #
# paged cache vs dense reference
# ---------------------------------------------------------------------- #
def _random_prefill_cache(cfg, length, rng):
    """A fake dense prefill result: init_cache(cfg, 1, length) with random
    contents in every leaf."""
    cache = init_cache(cfg, 1, length)
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    filled = [
        rng.standard_normal(leaf.shape).astype(leaf.dtype) for leaf in leaves
    ]
    return jax.tree_util.tree_unflatten(treedef, filled), filled


def _random_slices(kv, rng):
    """One decode step's write for one lane, shaped as the scheduler's
    lane decoder emits it."""
    out = []
    for i in range(kv.num_leaves):
        if kv.paged[i]:
            a = kv._arenas[i]
            out.append(
                rng.standard_normal((a.shape[1],) + a.shape[3:]).astype(
                    kv._dtypes[i]
                )
            )
        else:
            out.append(
                rng.standard_normal(kv._state_shape[i]).astype(kv._dtypes[i])
            )
    return out


class _DenseRef:
    """Parallel dense reference: per-sequence leaf arrays grown position
    by position, bit-for-bit what PagedKVCache must reproduce."""

    def __init__(self, kv):
        self.kv = kv
        self.seqs = {}

    def prefill(self, rid, flat, length):
        self.seqs[rid] = {
            "len": length,
            "leaves": [
                leaf[:, :, :length].copy() if self.kv.paged[i] else leaf.copy()
                for i, leaf in enumerate(flat)
            ],
        }

    def append(self, rid, slices, position):
        s = self.seqs[rid]
        for i, sl in enumerate(slices):
            if self.kv.paged[i]:
                cur = s["leaves"][i]
                if position >= cur.shape[2]:
                    pad = np.zeros(
                        cur.shape[:2] + (position + 1 - cur.shape[2],) + cur.shape[3:],
                        cur.dtype,
                    )
                    cur = np.concatenate([cur, pad], axis=2)
                cur[:, 0, position] = sl
                s["leaves"][i] = cur
            else:
                s["leaves"][i] = sl.copy()
        s["len"] = max(s["len"], position + 1)

    def check(self, rid):
        s = self.seqs[rid]
        got, _ = jax.tree_util.tree_flatten(self.kv.read_dense(rid))
        assert self.kv.seq_len[rid] == s["len"]
        for i, (g, r) in enumerate(zip(got, s["leaves"])):
            if self.kv.paged[i]:
                np.testing.assert_array_equal(
                    g[:, :, : s["len"]], r[:, :, : s["len"]], err_msg=f"leaf {i}"
                )
            else:
                np.testing.assert_array_equal(g, r, err_msg=f"leaf {i}")


@pytest.fixture(scope="module")
def gqa_cfg():
    return get_config("llama3.2-3b", reduced=True)


@pytest.fixture(scope="module")
def mamba_cfg():
    return get_config("mamba2-1.3b", reduced=True)


@settings(max_examples=5)
@given(seed=st.integers(0, 10_000), page_size=st.integers(2, 6))
def test_random_ops_match_dense_reference(gqa_cfg, seed, page_size):
    rng = np.random.default_rng(seed)
    max_len = 4 * page_size
    kv = PagedKVCache(gqa_cfg, num_pages=14, page_size=page_size, max_len=max_len)
    ref = _DenseRef(kv)
    live, parked, next_rid = [], [], 0
    for _ in range(50):
        kv.allocator.check()
        op = rng.random()
        if op < 0.35 or not live:
            P = int(rng.integers(1, max_len // 2 + 1))
            rid = f"q{next_rid}"
            if not kv.can_alloc(P) or kv.allocator.num_free < kv.pages_needed(P):
                continue
            assert kv.alloc_seq(rid, P)
            cache, flat = _random_prefill_cache(gqa_cfg, P, rng)
            kv.write_prefill(rid, cache, P)
            ref.prefill(rid, flat, P)
            live.append(rid)
            next_rid += 1
        elif op < 0.70:
            rid = live[int(rng.integers(len(live)))]
            posn = kv.seq_len[rid]
            if posn >= max_len or not kv.ensure_capacity(rid, posn + 1):
                continue
            sl = _random_slices(kv, rng)
            kv.append_token(rid, sl, posn)
            ref.append(rid, sl, posn)
        elif op < 0.82 and live:
            rid = live.pop(int(rng.integers(len(live))))
            kv.evict(rid)
            parked.append(rid)
        elif op < 0.90 and parked:
            rid = parked[int(rng.integers(len(parked)))]
            if kv.resume(rid):
                parked.remove(rid)
                live.append(rid)
                ref.check(rid)  # resume must be lossless
        elif live:
            rid = live.pop(int(rng.integers(len(live))))
            kv.free_seq(rid)
            del ref.seqs[rid]
        if live:
            ref.check(live[int(rng.integers(len(live)))])
    for rid in live:
        kv.free_seq(rid)
    for rid in parked:
        assert kv.resume(rid)
        ref.check(rid)
        kv.free_seq(rid)
    # nothing leaks
    assert kv.allocator.num_free == kv.allocator.num_pages
    kv.allocator.check()


@settings(max_examples=3)
@given(seed=st.integers(0, 10_000))
def test_state_leaves_roundtrip_mamba(mamba_cfg, seed):
    """Mamba conv/ssm state has no sequence axis: it must classify as
    per-sequence state and survive evict/resume bit-for-bit."""
    rng = np.random.default_rng(seed)
    kv = PagedKVCache(mamba_cfg, num_pages=8, page_size=4, max_len=16)
    assert any(not p for p in kv.paged), "mamba must have state leaves"
    ref = _DenseRef(kv)
    assert kv.alloc_seq("m0", 5)
    cache, flat = _random_prefill_cache(mamba_cfg, 5, rng)
    kv.write_prefill("m0", cache, 5)
    ref.prefill("m0", flat, 5)
    for posn in range(5, 9):
        sl = _random_slices(kv, rng)
        kv.append_token("m0", sl, posn)
        ref.append("m0", sl, posn)
    ref.check("m0")
    kv.evict("m0")
    assert kv.is_parked("m0")
    assert kv.resume("m0")
    ref.check("m0")
    kv.free_seq("m0")
    kv.allocator.check()


def test_gather_pads_with_zero_page(gqa_cfg):
    """The batch view for a short sequence is zero beyond its pages — the
    dense-reference property the masked decode relies on."""
    kv = PagedKVCache(gqa_cfg, num_pages=8, page_size=4, max_len=16)
    rng = np.random.default_rng(0)
    assert kv.alloc_seq("a", 3)
    cache, _ = _random_prefill_cache(gqa_cfg, 3, rng)
    kv.write_prefill("a", cache, 3)
    view = kv.gather(["a", None])
    leaves, _ = jax.tree_util.tree_flatten(view)
    ref_leaves, _ = jax.tree_util.tree_flatten(kv.read_dense("a", s_max=16))
    for i, (v, r) in enumerate(zip(leaves, ref_leaves)):
        if kv.paged[i]:
            assert v.shape[1] == 2 and v.shape[2] == 16
            np.testing.assert_array_equal(v[:, :1], r, err_msg=f"leaf {i}")
            assert not np.any(v[:, 1])  # empty lane all zeros
            assert not np.any(v[:, 0, 3:])  # beyond written length
        else:
            np.testing.assert_array_equal(v[:, :1], r, err_msg=f"leaf {i}")
    kv.free_seq("a")


def test_capacity_failures_are_clean(gqa_cfg):
    kv = PagedKVCache(gqa_cfg, num_pages=4, page_size=4, max_len=16)
    assert kv.alloc_seq("a", 12)  # 3 pages
    assert not kv.alloc_seq("b", 8)  # needs 2, only 1 free — clean refusal
    assert "b" not in kv.page_table and kv.allocator.num_free == 1
    assert kv.alloc_seq("c", 4)
    assert not kv.ensure_capacity("c", 8)  # growth refusal leaves state
    assert len(kv.page_table["c"]) == 1
    with pytest.raises(ValueError):
        kv.alloc_seq("d", 17)  # beyond max_len
    with pytest.raises(ValueError):
        PagedKVCache(gqa_cfg, num_pages=2, page_size=4, max_len=16)
    kv.free_seq("a")
    kv.free_seq("c")
    kv.allocator.check()


def test_encdec_rejected():
    cfg = get_config("seamless-m4t-large-v2", reduced=True)
    with pytest.raises(ValueError):
        PagedKVCache(cfg, num_pages=4, page_size=4, max_len=8)


# ---------------------------------------------------------------------- #
# bugfix sweep: zero-token allocs, zero-init bandwidth, typed exhaustion,
# finish-while-parked
# ---------------------------------------------------------------------- #
def test_pages_needed_zero_holds_no_page(gqa_cfg):
    """pages_needed(0) used to return 1, so a zero-token allocation held a
    page forever; it must hold nothing and grow only when asked to."""
    kv = PagedKVCache(gqa_cfg, num_pages=4, page_size=4, max_len=16)
    assert kv.pages_needed(0) == 0
    assert kv.alloc_seq("z", 0)
    assert kv.page_table["z"] == []
    assert kv.allocator.num_held == 0
    assert kv.ensure_capacity("z", 1)
    assert len(kv.page_table["z"]) == 1
    kv.free_seq("z")
    assert kv.allocator.num_free == 4
    kv.allocator.check()


def test_prefill_path_does_not_double_zero(gqa_cfg):
    """alloc_seq(zero=False) + write_prefill must touch each page exactly
    once (the write, plus one partial-tail memset) — no full-page zeroing
    of pages the prefill immediately overwrites — while the gathered view
    stays zero beyond the written length."""
    kv = PagedKVCache(gqa_cfg, num_pages=8, page_size=4, max_len=16)
    rng = np.random.default_rng(0)
    assert kv.alloc_seq("a", 10, zero=False)
    assert kv.zero_writes == 0
    cache, _ = _random_prefill_cache(gqa_cfg, 10, rng)
    kv.write_prefill("a", cache, 10)
    assert kv.zero_writes == 0
    view = kv.gather(["a"])
    leaves, _ = jax.tree_util.tree_flatten(view)
    for i, v in enumerate(leaves):
        if kv.paged[i]:
            assert not np.any(v[:, 0, 10:]), f"leaf {i} dirty beyond prefill"
    # the default (decode-growth) path still zeroes recycled pages
    assert kv.alloc_seq("b", 3)
    assert kv.zero_writes == 1
    kv.free_seq("a")
    kv.free_seq("b")
    kv.allocator.check()


def test_exhaustion_is_typed(gqa_cfg):
    """Capacity failures inside writes raise PagesExhausted (a RuntimeError
    the scheduler catches to evict per policy), never a bare RuntimeError."""
    kv = PagedKVCache(gqa_cfg, num_pages=4, page_size=4, max_len=16)
    rng = np.random.default_rng(0)
    assert kv.alloc_seq("a", 16)  # whole pool
    cache, _ = _random_prefill_cache(gqa_cfg, 16, rng)
    kv.write_prefill("a", cache, 16)
    assert kv.alloc_seq("b", 0)
    with pytest.raises(PagesExhausted):
        kv.append_token("b", _random_slices(kv, rng), 0)
    with pytest.raises(PagesExhausted):
        kv.write_prefill("b", cache, 4)
    assert issubclass(PagesExhausted, RuntimeError)
    # the failed writes left "b" consistent: still zero pages, still usable
    assert kv.page_table["b"] == [] and kv.seq_len["b"] == 0
    kv.free_seq("a")
    kv.append_token("b", _random_slices(kv, rng), 0)
    kv.free_seq("b")
    kv.allocator.check()


def test_finish_while_parked_does_not_double_free(gqa_cfg):
    """A request evicted and then finished (client cancel, max-tokens cut)
    must release its parked copies without touching the allocator twice."""
    kv = PagedKVCache(gqa_cfg, num_pages=8, page_size=4, max_len=16)
    rng = np.random.default_rng(1)
    assert kv.alloc_seq("a", 6)
    cache, _ = _random_prefill_cache(gqa_cfg, 6, rng)
    kv.write_prefill("a", cache, 6)
    kv.evict("a")
    assert kv.is_parked("a")
    assert kv.allocator.num_held == 0  # private pages freed at evict
    kv.free_seq("a")  # finish-while-parked: drops the parked copies
    assert not kv.is_parked("a")
    assert kv.allocator.num_free == 8
    with pytest.raises(KeyError):
        kv.free_seq("a")  # second finish is a real bug, loudly
    kv.allocator.check()


# ---------------------------------------------------------------------- #
# prefix sharing + copy-on-write
# ---------------------------------------------------------------------- #
def _det_cache(cfg, kv, tokens):
    """Dense prefill cache whose paged-leaf contents are a pure function of
    (leaf, position, token id): two prompts agreeing on a token prefix get
    bit-identical content over it, so a shared page (written by another
    request) is indistinguishable from a recomputed one — exactly the
    serving situation the COW property test models."""
    P = len(tokens)
    cache = init_cache(cfg, 1, P)
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    out = []
    for i, leaf in enumerate(leaves):
        arr = np.zeros(leaf.shape, leaf.dtype)
        if kv.paged[i]:
            for pos in range(P):
                r = np.random.default_rng(
                    (i * 7919 + pos) * 65537 + int(tokens[pos])
                )
                arr[:, 0, pos] = r.standard_normal(
                    arr.shape[:1] + arr.shape[3:]
                ).astype(arr.dtype)
        else:
            r = np.random.default_rng(hash(tuple(int(t) for t in tokens)) % 2**32)
            arr[...] = r.standard_normal(arr.shape).astype(arr.dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), out


def test_prefix_sharing_allocates_prefix_once_and_cow_isolates(gqa_cfg):
    kv = PagedKVCache(
        gqa_cfg, num_pages=12, page_size=4, max_len=16, prefix_sharing=True
    )
    rng = np.random.default_rng(3)
    tokens = rng.integers(1, 50, 12)
    cache, _ = _det_cache(gqa_cfg, kv, tokens)
    assert kv.alloc_seq("r1", 12, tokens=tokens, zero=False)
    assert kv.seq_len["r1"] == 0  # index empty: nothing shared yet
    kv.write_prefill("r1", cache, 12)

    assert kv.alloc_seq("r2", 12, tokens=tokens, zero=False)
    # cap = (12-1)//4 = 2 pages shared; the last-token page is recomputed
    assert kv.seq_len["r2"] == 8
    assert kv.page_table["r2"][:2] == kv.page_table["r1"][:2]
    assert kv.allocator.num_held == 4  # 3 (r1) + 1 (r2 tail), not 6
    assert kv.share_stats["prefix_hits"] == 1
    assert kv.share_stats["pages_shared"] == 2
    kv.write_prefill("r2", cache, 12, start=8)
    ref1, _ = jax.tree_util.tree_flatten(kv.read_dense("r1"))
    ref2, _ = jax.tree_util.tree_flatten(kv.read_dense("r2"))
    for a, b in zip(ref1, ref2):
        np.testing.assert_array_equal(a, b)

    # write INTO the shared span: r2 gets a private copy, r1 is untouched
    sl = _random_slices(kv, rng)
    kv.append_token("r2", sl, 5)  # page 1, refcount 2 -> COW
    assert kv.share_stats["cow_copies"] == 1
    assert kv.page_table["r2"][1] != kv.page_table["r1"][1]
    got1, _ = jax.tree_util.tree_flatten(kv.read_dense("r1"))
    for a, b in zip(got1, ref1):
        np.testing.assert_array_equal(a, b)  # sibling bit-identical
    got2, _ = jax.tree_util.tree_flatten(kv.read_dense("r2"))
    for i, (a, b) in enumerate(zip(got2, ref2)):
        if kv.paged[i]:
            np.testing.assert_array_equal(a[:, 0, 5], sl[i])

    # eviction keeps the still-shared page resident by reference
    kv.evict("r2")
    assert kv.parked_shared_pages("r2") == 1  # page 0 only (page 1 COWed)
    assert kv.resume("r2")
    got2b, _ = jax.tree_util.tree_flatten(kv.read_dense("r2"))
    for a, b in zip(got2b, got2):
        np.testing.assert_array_equal(a, b)

    # freeing the registrant keeps the page alive under r2's refcount
    p0 = kv.page_table["r1"][0]
    kv.free_seq("r1")
    assert kv.page_table["r2"][0] == p0
    kv.free_seq("r2")
    assert kv.allocator.num_free == 12
    kv.allocator.check()


def test_release_parked_shared_frees_pages(gqa_cfg):
    """The terminal-pressure escape valve: a parked sequence's retained
    shared refs demote to host copies (freeing sole-owned pages) and the
    sequence still resumes bit-for-bit."""
    kv = PagedKVCache(
        gqa_cfg, num_pages=8, page_size=4, max_len=16, prefix_sharing=True
    )
    tokens = np.arange(1, 13)
    cache, _ = _det_cache(gqa_cfg, kv, tokens)
    assert kv.alloc_seq("w", 12, tokens=tokens, zero=False)
    kv.write_prefill("w", cache, 12)
    assert kv.alloc_seq("s", 12, tokens=tokens, zero=False)
    kv.write_prefill("s", cache, 12, start=8)
    want, _ = jax.tree_util.tree_flatten(kv.read_dense("s"))
    kv.evict("s")
    kv.free_seq("w")  # shared pages now held only by the parked "s"
    held_before = kv.allocator.num_held
    assert kv.release_parked_shared("s") == 2
    assert kv.allocator.num_held < held_before  # refcount hit 0 -> freed
    assert kv.resume("s")
    got, _ = jax.tree_util.tree_flatten(kv.read_dense("s"))
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)
    kv.free_seq("s")
    assert kv.allocator.num_free == 8
    kv.allocator.check()


@settings(max_examples=4)
@given(seed=st.integers(0, 10_000))
def test_shared_prefix_random_ops_match_dense_reference(gqa_cfg, seed):
    """The COW analogue of the paged-vs-dense property: random
    interleavings of {submit-with-shared-prefix, decode-append, overwrite
    (COW trigger), evict, resume, finish, finish-while-parked} keep every
    per-request view bit-identical to an unshared dense reference."""
    rng = np.random.default_rng(seed)
    ps = 4
    max_len = 24
    kv = PagedKVCache(
        gqa_cfg, num_pages=40, page_size=ps, max_len=max_len,
        prefix_sharing=True,
    )
    ref = _DenseRef(kv)
    vocab = 40
    families = [rng.integers(1, vocab, 8), rng.integers(1, vocab, 12)]
    live, parked, n = [], [], 0
    for _ in range(60):
        kv.allocator.check()
        op = rng.random()
        if op < 0.30 or not live:
            fam = families[int(rng.integers(len(families)))]
            suffix = rng.integers(1, vocab, int(rng.integers(1, 5)))
            tokens = np.concatenate([fam, suffix])
            P = len(tokens)
            rid = f"s{n}"
            if not kv.alloc_seq(rid, P, tokens=tokens, zero=False):
                continue
            n += 1
            start = kv.seq_len[rid]
            cache, flat = _det_cache(gqa_cfg, kv, tokens)
            kv.write_prefill(rid, cache, P, start=start)
            ref.prefill(rid, flat, P)
            live.append(rid)
        elif op < 0.55:
            rid = live[int(rng.integers(len(live)))]
            posn = kv.seq_len[rid]
            if posn >= max_len:
                continue
            sl = _random_slices(kv, rng)
            try:
                kv.append_token(rid, sl, posn)
            except PagesExhausted:
                continue
            ref.append(rid, sl, posn)
        elif op < 0.65:
            # overwrite a position inside the (possibly shared) span:
            # COW must keep every sibling's view bit-identical
            rid = live[int(rng.integers(len(live)))]
            posn = int(rng.integers(0, kv.seq_len[rid]))
            sl = _random_slices(kv, rng)
            try:
                kv.append_token(rid, sl, posn)
            except PagesExhausted:
                continue
            ref.append(rid, sl, posn)
        elif op < 0.78:
            rid = live.pop(int(rng.integers(len(live))))
            kv.evict(rid)
            parked.append(rid)
        elif op < 0.86 and parked:
            rid = parked[int(rng.integers(len(parked)))]
            if kv.resume(rid):
                parked.remove(rid)
                live.append(rid)
                ref.check(rid)  # resume must be lossless
        elif op < 0.93 and parked:
            rid = parked.pop(int(rng.integers(len(parked))))
            kv.free_seq(rid)  # finish-while-parked
            del ref.seqs[rid]
        elif live:
            rid = live.pop(int(rng.integers(len(live))))
            kv.free_seq(rid)
            del ref.seqs[rid]
        for check_rid in live:
            ref.check(check_rid)
    for rid in live:
        ref.check(rid)
        kv.free_seq(rid)
    for rid in parked:
        assert kv.resume(rid)
        ref.check(rid)
        kv.free_seq(rid)
    assert kv.allocator.num_free == kv.allocator.num_pages
    kv.allocator.check()
