"""VBR format: round trips, indirection arrays, structure hashing."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # keep deterministic cases running without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core import vbr as vbrlib


def test_paper_fig3_example():
    """The 11x11 matrix of Fig. 3 with its block partition."""
    rpntr = [0, 2, 5, 6, 9, 11]
    cpntr = [0, 2, 5, 6, 9, 11]
    dense = np.array(
        [
            [4, 2, 0, 0, 0, 1, 0, 0, 0, -1, 1],
            [1, 5, 0, 0, 0, 2, 0, 0, 0, 0, -1],
            [0, 0, 6, 1, 2, 2, 0, 0, 0, 0, 0],
            [0, 0, 2, 7, 1, 0, 0, 0, 0, 0, 0],
            [0, 0, -1, 2, 9, 3, 0, 0, 0, 0, 0],
            [2, 1, 3, 4, 5, 10, 4, 3, 2, 0, 0],
            [0, 0, 0, 0, 0, 4, 13, 4, 2, 0, 0],
            [0, 0, 0, 0, 0, 3, 3, 11, 3, 0, 0],
            [0, 0, 0, 0, 0, 0, 2, 0, 7, 0, 0],
            [8, 4, 0, 0, 0, 0, 0, 0, 0, 25, 3],
            [-2, 3, 0, 0, 0, 0, 0, 0, 0, 8, 12],
        ],
        dtype=np.float32,
    )
    v = vbrlib.from_dense(dense, rpntr, cpntr)
    # paper-stated indirection arrays
    np.testing.assert_array_equal(v.bindx, [0, 2, 4, 1, 2, 0, 1, 2, 3, 2, 3, 0, 4])
    np.testing.assert_array_equal(v.bpntrb, [0, 3, 5, 9, 11])
    np.testing.assert_array_equal(v.bpntre, [3, 5, 9, 11, 13])
    np.testing.assert_array_equal(
        v.indx, [0, 4, 6, 10, 19, 22, 24, 27, 28, 31, 34, 43, 47, 51]
    )
    # val is column-major per block (paper's Val array prefix)
    np.testing.assert_array_equal(v.val[:10], [4, 1, 2, 5, 1, 2, -1, 0, 1, -1])
    np.testing.assert_array_equal(v.to_dense(), dense)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(4, 60),
    cols=st.integers(4, 60),
    rs=st.integers(1, 8),
    cs=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    uniform=st.booleans(),
    sparsity=st.floats(0.0, 0.9),
)
def test_roundtrip_property(rows, cols, rs, cs, seed, uniform, sparsity):
    nb = max(1, (rs * cs) // 2)
    v = vbrlib.synthesize(rows, cols, rs, cs, nb, sparsity, uniform, seed)
    d = v.to_dense()
    v2 = vbrlib.from_dense(d, v.rpntr, v.cpntr)
    np.testing.assert_allclose(v2.to_dense(), d)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_structure_hash_ignores_values(seed):
    v1 = vbrlib.synthesize(40, 40, 4, 4, 8, seed=seed)
    v2 = vbrlib.VBR(**{**v1.__dict__})
    v2.val = v1.val * 3.7 + 1.0  # same pattern, new values
    assert vbrlib.structure_hash(v1) == vbrlib.structure_hash(v2)
    v3 = vbrlib.synthesize(40, 40, 4, 4, 8, seed=seed + 1)
    if not np.array_equal(v3.bindx, v1.bindx):
        assert vbrlib.structure_hash(v3) != vbrlib.structure_hash(v1)


def test_block_iterator_covers_stored_values():
    v = vbrlib.synthesize(50, 70, 5, 7, 12, seed=3)
    seen = np.zeros(v.stored_nnz, dtype=bool)
    for t in v.blocks():
        assert t.size == t.height * t.width
        seen[t.val_offset : t.val_offset + t.size] = True
    assert seen.all()


def test_empty_block_rows():
    dense = np.zeros((10, 10), dtype=np.float32)
    dense[7, 3] = 2.0
    v = vbrlib.from_dense(dense, [0, 5, 10], [0, 5, 10])
    assert v.bpntrb[0] == -1  # first block row empty
    np.testing.assert_array_equal(v.to_dense(), dense)


def test_density_metric():
    v = vbrlib.synthesize(100, 100, 5, 5, 10, block_sparsity=0.5, seed=0)
    assert 0.3 < v.density() < 0.7
