"""Optimizer, schedule, data pipeline, checkpointing, train loop."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager, save_checkpoint, restore_checkpoint
from repro.data.pipeline import FileDataset, Prefetcher, SyntheticDataset
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.train.loop import StepMonitor, TrainLoop


# ------------------------------ optimizer ----------------------------- #
def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)
    grad_fn = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))
    for _ in range(200):
        params, state, _ = adamw_update(params, grad_fn(params), state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=1e-2)


def test_adamw_clipping():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params, cfg)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, m = adamw_update(params, g, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(100.0)


def test_adamw_bf16_states():
    cfg = AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.zeros((4, 4), jnp.float32)}
    state = adamw_init(params, cfg)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    p2, s2, _ = adamw_update(params, {"w": jnp.ones((4, 4))}, state, cfg)
    assert s2["nu"]["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.float32


def test_cosine_schedule():
    assert float(cosine_schedule(0, 1.0, 10, 100)) == 0.0
    assert float(cosine_schedule(10, 1.0, 10, 100)) == pytest.approx(1.0)
    assert float(cosine_schedule(100, 1.0, 10, 100)) == pytest.approx(0.1)


# ------------------------------ data ---------------------------------- #
def test_synthetic_deterministic_and_resumable():
    d1 = SyntheticDataset(1000, 16, 4, seed=7)
    it = iter(d1)
    first = [next(it) for _ in range(3)]
    d2 = SyntheticDataset(1000, 16, 4, seed=7)
    d2.load_state_dict({"step": 2})
    b = next(iter(d2))
    np.testing.assert_array_equal(b["tokens"], first[2]["tokens"])


def test_synthetic_host_sharding_differs():
    a = SyntheticDataset(1000, 16, 4, seed=0, host_id=0, num_hosts=2)
    b = SyntheticDataset(1000, 16, 4, seed=0, host_id=1, num_hosts=2)
    assert not np.array_equal(next(iter(a))["tokens"], next(iter(b))["tokens"])


def test_labels_shift():
    d = SyntheticDataset(1000, 16, 2, seed=1)
    b = next(iter(d))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_file_dataset(tmp_path):
    toks = (np.arange(10_000) % 251).astype(np.uint16)
    p = tmp_path / "data.bin"
    toks.tofile(p)
    ds = FileDataset(str(p), seq_len=32, batch=4, seed=0)
    b1 = next(iter(ds))
    assert b1["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    ds2 = FileDataset(str(p), seq_len=32, batch=4, seed=0)
    np.testing.assert_array_equal(next(iter(ds2))["tokens"], b1["tokens"])


def test_prefetcher():
    ds = SyntheticDataset(100, 8, 2, seed=0)
    pf = Prefetcher(iter(ds), depth=2)
    batches = [next(pf) for _ in range(5)]
    assert len(batches) == 5
    pf.close()


# ------------------------------ checkpoint ---------------------------- #
def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nest": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": jnp.int32(7)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t, extra={"data": {"step": 5}})
    restored, step, extra = restore_checkpoint(str(tmp_path), t)
    assert step == 5 and extra["data"]["step"] == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp.")]


def test_checkpoint_manager_async_and_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_last_k=2)
    for s in (1, 2, 3, 4):
        m.save_async(s, _tree())
    m.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    assert m.latest_step() == 4


def test_checkpoint_resharding_restore(tmp_path):
    """Elastic restore: load with explicit shardings for the current mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = {"w": jnp.arange(8.0)}
    save_checkpoint(str(tmp_path), 0, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _, _ = restore_checkpoint(str(tmp_path), t, shardings=sh)
    assert restored["w"].sharding == sh["w"]


# ------------------------------ train loop ---------------------------- #
def test_step_monitor_flags_straggler():
    mon = StepMonitor(window=16, threshold=3.0)
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 1.0)  # 10x median
    assert 10 in mon.flagged


def test_train_loop_preemption_resume(tmp_path):
    """Kill-and-restart resumes bit-exact (fault tolerance contract)."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import init_params
    from repro.train.step import make_train_step

    cfg = get_config("llama3.2-3b", reduced=True)
    oc = AdamWConfig(lr=1e-3)
    step = jax.jit(make_train_step(cfg, oc))

    def make(dsseed=3):
        params = init_params(cfg, jax.random.PRNGKey(1))
        opt = adamw_init(params, oc)
        ds = SyntheticDataset(cfg.vocab_size, 16, 4, seed=dsseed)
        wrapped = lambda p, o, b, i: step(p, o, b, jnp.int32(i))
        loop = TrainLoop(wrapped, ds, ckpt_dir=str(tmp_path), ckpt_every=5)
        return params, opt, loop

    # run 10 steps straight
    p, o, loop = make()
    p10, o10, m10 = loop.run(p, o, 10, log_every=0)

    # "preempt" at 5: fresh process state, restore, run remaining 5
    import shutil

    shutil.rmtree(tmp_path)
    p, o, loop = make()
    p5, o5, _ = loop.run(p, o, 5, log_every=0)
    p2, o2, loop2 = make()
    p2, o2, resumed = loop2.maybe_restore(p2, o2)
    assert resumed and loop2.step == 5
    pr, orr, mr = loop2.run(p2, o2, 5, log_every=0)
    assert float(mr["loss"]) == pytest.approx(float(m10["loss"]), rel=1e-5)
