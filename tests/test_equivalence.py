"""Property-based differential equivalence over randomized VBR structures.

Every staging backend x every execution mode {unsharded, sharded host
loop, 1-D mesh, 2-D (shards x model) mesh} must agree with the dense
reference, over generated structures spanning varying block-size
distributions, empty block rows, and dense/hyper-sparse extremes; and the
partitioner's balance bound must hold as an invariant (Ahrens & Boman:
partition quality is a property of the structure, not of a hand-picked
example).

Runs under real hypothesis when installed; otherwise the deterministic
fixed-seed sampler in ``_hypothesis_stub`` replays the same properties,
so tier-1 keeps the coverage either way.  The mesh-path properties need
multiple devices and skip unless
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the multidevice
CI job).
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # keep deterministic sampling without hypothesis
    from _hypothesis_stub import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import vbr as vbrlib
from repro.core.staging import (
    StagingOptions,
    clear_cache,
    stage_spmm,
    stage_spmv,
)
from repro.distributed.partition import block_row_nnz, make_shard_plan

BACKENDS = ["unrolled", "grouped", "bucketed", "gather"]
TOL = dict(atol=3e-5, rtol=3e-5)


# module-scoped (NOT per-function: function-scoped fixtures don't mix with
# @given) cache isolation — sharded staging persists shard plans on disk
@pytest.fixture(scope="module", autouse=True)
def _cache_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("equiv-cache")
    old = os.environ.get("REPRO_CACHE_DIR")
    os.environ["REPRO_CACHE_DIR"] = str(d)
    clear_cache()
    yield
    clear_cache()
    if old is None:
        os.environ.pop("REPRO_CACHE_DIR", None)
    else:
        os.environ["REPRO_CACHE_DIR"] = old


def _structure(rows, cols, rs, cs, nb_frac, sparsity, uniform, seed):
    """Random VBR with a controlled block count: nb_frac sweeps from
    hyper-sparse (a single stored block, most block rows empty) to fully
    dense (every grid cell stored)."""
    nb = max(1, int(round(nb_frac * rs * cs)))
    return vbrlib.synthesize(
        rows, cols, rs, cs, nb, sparsity, uniform, seed=seed
    )


def _inputs(v, n_cols=None, seed=0):
    rng = np.random.default_rng(seed)
    if n_cols is None:
        return jnp.asarray(rng.standard_normal(v.shape[1]).astype(np.float32))
    return jnp.asarray(
        rng.standard_normal((v.shape[1], n_cols)).astype(np.float32)
    )


# --------------------------------------------------------------------- #
# backends x dense reference
# --------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(6, 72),
    cols=st.integers(6, 72),
    rs=st.integers(1, 8),
    cs=st.integers(1, 8),
    nb_frac=st.floats(0.05, 1.0),
    sparsity=st.floats(0.0, 0.95),
    uniform=st.booleans(),
    seed=st.integers(0, 100_000),
)
def test_spmv_backends_match_dense(
    rows, cols, rs, cs, nb_frac, sparsity, uniform, seed
):
    v = _structure(rows, cols, rs, cs, nb_frac, sparsity, uniform, seed)
    x = _inputs(v, seed=seed)
    ref = v.to_dense() @ np.asarray(x)
    val = jnp.asarray(v.val)
    for backend in BACKENDS:
        got = np.asarray(stage_spmv(v, StagingOptions(backend=backend))(val, x))
        np.testing.assert_allclose(got, ref, err_msg=backend, **TOL)


@settings(max_examples=8, deadline=None)
@given(
    rows=st.integers(6, 64),
    cols=st.integers(6, 64),
    rs=st.integers(1, 6),
    cs=st.integers(1, 6),
    nb_frac=st.floats(0.1, 1.0),
    sparsity=st.floats(0.0, 0.9),
    n_cols=st.sampled_from([1, 3, 8]),
    seed=st.integers(0, 100_000),
)
def test_spmm_backends_match_dense(
    rows, cols, rs, cs, nb_frac, sparsity, n_cols, seed
):
    v = _structure(rows, cols, rs, cs, nb_frac, sparsity, False, seed)
    X = _inputs(v, n_cols=n_cols, seed=seed)
    ref = v.to_dense() @ np.asarray(X)
    val = jnp.asarray(v.val)
    for backend in ["unrolled", "grouped", "bucketed", "gather"]:
        got = np.asarray(
            stage_spmm(v, n_cols, StagingOptions(backend=backend))(val, X)
        )
        np.testing.assert_allclose(got, ref, err_msg=backend, **TOL)


# --------------------------------------------------------------------- #
# sharded (host loop) x dense reference + balance invariant
# --------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(12, 96),
    cols=st.integers(8, 64),
    rs=st.integers(2, 10),
    cs=st.integers(1, 8),
    nb_frac=st.floats(0.05, 1.0),
    sparsity=st.floats(0.0, 0.9),
    num_shards=st.integers(1, 8),
    strategy=st.sampled_from(["lpt", "contiguous"]),
    seed=st.integers(0, 100_000),
)
def test_sharded_host_matches_dense(
    rows, cols, rs, cs, nb_frac, sparsity, num_shards, strategy, seed
):
    v = _structure(rows, cols, rs, cs, nb_frac, sparsity, False, seed)
    x = _inputs(v, seed=seed)
    ref = v.to_dense() @ np.asarray(x)
    got = np.asarray(
        stage_spmv(v, shards=num_shards, shard_strategy=strategy)(
            jnp.asarray(v.val), x
        )
    )
    np.testing.assert_allclose(got, ref, **TOL)


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(16, 120),
    cols=st.integers(8, 80),
    rs=st.integers(2, 12),
    cs=st.integers(1, 8),
    nb_frac=st.floats(0.05, 1.0),
    sparsity=st.floats(0.0, 0.9),
    num_shards=st.integers(2, 8),
    strategy=st.sampled_from(["lpt", "contiguous"]),
    seed=st.integers(0, 100_000),
)
def test_partition_invariants(
    rows, cols, rs, cs, nb_frac, sparsity, num_shards, strategy, seed
):
    """Unconditional: the shards tile the rows exactly and preserve nnz.
    Balance: worst shard <= ~1.5x mean whenever no single matrix row
    dominates the per-shard mean (rows are the splitting granularity — a
    single row heavier than a whole shard's fair share is unsplittable,
    so no partitioner could do better there)."""
    v = _structure(rows, cols, rs, cs, nb_frac, sparsity, False, seed)
    plan = make_shard_plan(v, num_shards, strategy)
    allrows = np.sort(np.concatenate([s.row_index for s in plan.shards]))
    np.testing.assert_array_equal(allrows, np.arange(v.shape[0]))
    assert int(plan.nnz_per_shard().sum()) == v.stored_nnz
    total = v.stored_nnz
    if total == 0:
        return
    sizes = block_row_nnz(v)
    heights = np.diff(v.rpntr)
    per_row_max = int((sizes // np.maximum(heights, 1)).max())
    if per_row_max * 3 * num_shards <= total:
        assert plan.imbalance() <= 1.5, (
            f"{strategy} x{num_shards}: imbalance {plan.imbalance():.3f}"
        )


# --------------------------------------------------------------------- #
# deterministic extremes (always run; no sampling needed)
# --------------------------------------------------------------------- #
def test_all_block_rows_empty():
    """A structure whose stored-block set is empty: y must be exactly 0."""
    v = vbrlib.from_dense(
        np.zeros((12, 10), np.float32), [0, 4, 8, 12], [0, 5, 10]
    )
    assert v.num_blocks == 0
    x = _inputs(v)
    for backend in ["unrolled", "grouped", "gather"]:
        got = np.asarray(
            stage_spmv(v, StagingOptions(backend=backend))(jnp.asarray(v.val), x)
        )
        np.testing.assert_array_equal(got, np.zeros(12, np.float32))
    got = np.asarray(stage_spmv(v, shards=4)(jnp.asarray(v.val), x))
    np.testing.assert_array_equal(got, np.zeros(12, np.float32))


def test_fully_dense_extreme():
    """Every grid cell stored (block-dense): matches a plain dense matmul."""
    v = _structure(24, 20, 4, 4, 1.0, 0.0, True, seed=3)
    assert v.num_blocks == 16
    x = _inputs(v)
    ref = v.to_dense() @ np.asarray(x)
    for backend in BACKENDS:
        got = np.asarray(
            stage_spmv(v, StagingOptions(backend=backend))(jnp.asarray(v.val), x)
        )
        np.testing.assert_allclose(got, ref, **TOL)


def test_hyper_sparse_extreme_with_hybrid():
    """A single stored block, nearly all zeros: the density-threshold
    hybrid (COO tail) must agree with the dense path."""
    v = _structure(40, 40, 8, 8, 1 / 64, 0.97, False, seed=11)
    assert v.num_blocks == 1
    x = _inputs(v)
    ref = v.to_dense() @ np.asarray(x)
    plain = np.asarray(stage_spmv(v)(jnp.asarray(v.val), x))
    hybrid = np.asarray(
        stage_spmv(
            v, StagingOptions(backend="grouped", density_threshold=0.5)
        )(jnp.asarray(v.val), x)
    )
    np.testing.assert_allclose(plain, ref, **TOL)
    np.testing.assert_allclose(hybrid, ref, **TOL)


def test_skewed_block_size_distribution():
    """One giant block row next to many tiny ones — the distribution the
    bucketed backend and the row-splitting partitioner exist for."""
    dense = np.zeros((100, 60), np.float32)
    rng = np.random.default_rng(5)
    dense[:52, :60] = rng.standard_normal((52, 60))  # giant
    for i in range(12):
        dense[52 + 4 * i : 56 + 4 * i, :4] = rng.standard_normal((4, 4))
    v = vbrlib.from_dense(
        dense, [0, 52] + list(range(56, 104, 4)), [0, 4, 60]
    )
    x = _inputs(v)
    ref = dense @ np.asarray(x)
    for backend in BACKENDS:
        got = np.asarray(
            stage_spmv(v, StagingOptions(backend=backend))(jnp.asarray(v.val), x)
        )
        np.testing.assert_allclose(got, ref, err_msg=backend, **TOL)
    plan = make_shard_plan(v, 4)
    assert plan.imbalance() <= 1.5
    got = np.asarray(stage_spmv(v, shards=4)(jnp.asarray(v.val), x))
    np.testing.assert_allclose(got, ref, **TOL)


# --------------------------------------------------------------------- #
# reblocked layouts and the DIA-hybrid backend x dense reference
# --------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(8, 72),
    cols=st.integers(8, 72),
    rs=st.integers(1, 8),
    cs=st.integers(1, 8),
    nb_frac=st.floats(0.05, 1.0),
    sparsity=st.floats(0.0, 0.95),
    seed=st.integers(0, 100_000),
)
def test_reblocked_matches_dense(rows, cols, rs, cs, nb_frac, sparsity, seed):
    """Every reblocking proposal (dp and aligned, forced on) must be a
    pure re-layout: staged under any backend it reproduces the dense
    product of the ORIGINAL structure from the ORIGINAL value array."""
    from repro.core import reblock as rblib

    v = _structure(rows, cols, rs, cs, nb_frac, sparsity, False, seed)
    specs = rblib.propose_reblockings(
        v, device="cpu", include_aligned=True, tile=(4, 8)
    )
    if not specs:
        return
    x = _inputs(v, seed=seed)
    ref = v.to_dense() @ np.asarray(x)
    val = jnp.asarray(v.val)
    for spec in specs:
        rvbr, _ = rblib.apply_reblock(v, spec)
        np.testing.assert_allclose(rvbr.to_dense(), v.to_dense(),
                                   err_msg=spec.strategy)
        for backend in ["grouped", "bucketed"]:
            k = rblib.stage_reblocked(
                v, spec, StagingOptions(backend=backend), "spmv", None
            )
            got = np.asarray(k(val, x))
            np.testing.assert_allclose(
                got, ref, err_msg=f"{spec.strategy}+{backend}", **TOL
            )


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(16, 80),
    bw=st.integers(0, 6),
    block=st.integers(1, 6),
    extra=st.integers(0, 30),
    seed=st.integers(0, 100_000),
)
def test_dia_hybrid_matches_dense(n, bw, block, extra, seed):
    """Banded-plus-noise structures through the DIA-hybrid split must
    match dense regardless of where the diagonal/remainder cut lands."""
    from repro.kernels.dia_hybrid import DiaHybridKernel

    rng = np.random.default_rng(seed)
    dense = np.zeros((n, n), np.float32)
    for i in range(n):
        lo, hi = max(0, i - bw), min(n, i + bw + 1)
        dense[i, lo:hi] = rng.standard_normal(hi - lo)
    ii = rng.integers(0, n, extra)
    jj = rng.integers(0, n, extra)
    dense[ii, jj] = rng.standard_normal(extra)
    splits = sorted({0, n, *range(0, n, block)})
    v = vbrlib.from_dense(dense, splits, splits)
    if v.num_blocks == 0:
        return
    # offsets pinned explicitly: equivalence must hold for ANY split,
    # not just the detector's preferred one
    k = DiaHybridKernel(v, offsets=tuple(range(-bw, bw + 1)))
    x = _inputs(v, seed=seed)
    got = np.asarray(k(jnp.asarray(v.val), x))
    np.testing.assert_allclose(got, v.to_dense() @ np.asarray(x), **TOL)


# --------------------------------------------------------------------- #
# mesh paths (multidevice CI: XLA_FLAGS=--xla_force_host_platform_
# device_count=8; skipped on a single-device tier-1 run)
# --------------------------------------------------------------------- #
needs8 = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 devices (multidevice CI job)"
)


@needs8
@settings(max_examples=5, deadline=None)
@given(
    rows=st.integers(24, 96),
    cols=st.integers(16, 64),
    rs=st.integers(3, 10),
    cs=st.integers(2, 8),
    nb_frac=st.floats(0.1, 0.9),
    sparsity=st.floats(0.0, 0.8),
    overlap=st.booleans(),
    seed=st.integers(0, 100_000),
)
def test_mesh_spmv_matches_dense(
    rows, cols, rs, cs, nb_frac, sparsity, overlap, seed
):
    from repro.launch.mesh import make_staging_mesh

    v = _structure(rows, cols, rs, cs, nb_frac, sparsity, False, seed)
    x = _inputs(v, seed=seed)
    ref = v.to_dense() @ np.asarray(x)
    val = jnp.asarray(v.val)
    for shape in [8, (4, 2), (2, 4)]:
        mesh = make_staging_mesh(shape)
        kern = stage_spmv(v, mesh=mesh, overlap_gather=overlap)
        got = np.asarray(jax.device_get(kern(val, x)))
        np.testing.assert_allclose(got, ref, err_msg=str(shape), **TOL)


@needs8
@settings(max_examples=5, deadline=None)
@given(
    rows=st.integers(24, 96),
    cols=st.integers(16, 64),
    rs=st.integers(3, 10),
    cs=st.integers(2, 8),
    nb_frac=st.floats(0.1, 0.9),
    sparsity=st.floats(0.0, 0.8),
    n_cols=st.sampled_from([8, 16]),
    overlap=st.booleans(),
    seed=st.integers(0, 100_000),
)
def test_mesh2d_spmm_matches_unsharded_and_1d(
    rows, cols, rs, cs, nb_frac, sparsity, n_cols, overlap, seed
):
    """The 2-D (shards x model) SpMM path is differentially checked
    against BOTH the unsharded staged kernel and the 1-D mesh path."""
    from repro.launch.mesh import make_staging_mesh

    v = _structure(rows, cols, rs, cs, nb_frac, sparsity, False, seed)
    X = _inputs(v, n_cols=n_cols, seed=seed)
    val = jnp.asarray(v.val)
    ref = np.asarray(stage_spmm(v, n_cols)(val, X))
    np.testing.assert_allclose(ref, v.to_dense() @ np.asarray(X), **TOL)
    got1d = np.asarray(
        jax.device_get(
            stage_spmm(
                v, n_cols, mesh=make_staging_mesh(8), overlap_gather=overlap
            )(val, X)
        )
    )
    np.testing.assert_allclose(got1d, ref, **TOL)
    for shape in [(4, 2), (2, 4)]:
        mesh = make_staging_mesh(shape)
        kern = stage_spmm(v, n_cols, mesh=mesh, overlap_gather=overlap)
        got2d = np.asarray(jax.device_get(kern(val, X)))
        np.testing.assert_allclose(got2d, ref, err_msg=str(shape), **TOL)
        np.testing.assert_allclose(got2d, got1d, err_msg=str(shape), **TOL)


@needs8
@settings(max_examples=5, deadline=None)
@given(
    rows=st.integers(24, 96),
    cols=st.integers(16, 64),
    rs=st.integers(3, 10),
    cs=st.integers(2, 8),
    nb_frac=st.floats(0.1, 0.9),
    sparsity=st.floats(0.0, 0.8),
    seed=st.integers(0, 100_000),
)
def test_mesh_spmv_on_reblocked_matches_dense(
    rows, cols, rs, cs, nb_frac, sparsity, seed
):
    """A reblocked VBR is a first-class structure: staging it over 1-D and
    2-D meshes must still match the ORIGINAL structure's dense product.
    (The ``ReblockedKernel`` wrapper itself is unsharded; mesh execution
    applies the re-layout host-side and stages the reblocked VBR.)"""
    from repro.core import reblock as rblib
    from repro.launch.mesh import make_staging_mesh

    v = _structure(rows, cols, rs, cs, nb_frac, sparsity, False, seed)
    specs = rblib.propose_reblockings(
        v, device="cpu", include_aligned=True, tile=(4, 8)
    )
    if not specs:
        return
    x = _inputs(v, seed=seed)
    ref = v.to_dense() @ np.asarray(x)
    for spec in specs:
        rvbr, _ = rblib.apply_reblock(v, spec)
        rval = jnp.asarray(rvbr.val)
        for shape in [8, (4, 2), (2, 4)]:
            mesh = make_staging_mesh(shape)
            kern = stage_spmv(rvbr, mesh=mesh)
            got = np.asarray(jax.device_get(kern(rval, x)))
            np.testing.assert_allclose(
                got, ref, err_msg=f"{spec.strategy}@{shape}", **TOL
            )
