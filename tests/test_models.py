"""Per-arch smoke tests + decode equivalence + training sanity."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    decode_step,
    encode,
    forward_train,
    init_cache,
    init_params,
    param_count,
    prefill,
)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one grad step; shapes + finiteness."""
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, KEY)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.is_encdec:
        batch["src_embeds"] = jax.random.normal(KEY, (B, 8, cfg.frontend_dim))
    logits, aux = forward_train(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    from repro.train.step import make_train_step
    from repro.optim.adamw import AdamWConfig, adamw_init

    oc = AdamWConfig(lr=1e-3)
    step = make_train_step(cfg, oc)
    opt = adamw_init(params, oc)
    p2, o2, m = jax.jit(step)(params, opt, batch, jnp.int32(0))
    assert bool(jnp.isfinite(m["loss"]))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(l0, np.float32), np.asarray(l1, np.float32))


FULL_SIZES = {
    "nemotron-4-15b": 15.6e9,
    "llama3.2-3b": 3.2e9,
    "granite-8b": 8.1e9,
    "llama3-8b": 8.0e9,
    "mamba2-1.3b": 1.3e9,
    "jamba-1.5-large-398b": 398.6e9,
    "deepseek-v2-236b": 235.7e9,
    "llama4-scout-17b-a16e": 107.8e9,
    "chameleon-34b": 34.3e9,
    "seamless-m4t-large-v2": 1.4e9,
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count(arch):
    """FULL configs match their published sizes (no allocation)."""
    cfg = get_config(arch, reduced=False)
    n = param_count(cfg)
    assert abs(n - FULL_SIZES[arch]) / FULL_SIZES[arch] < 0.1


@pytest.mark.parametrize(
    "arch",
    ["llama3-8b", "deepseek-v2-236b", "mamba2-1.3b", "jamba-1.5-large-398b",
     "seamless-m4t-large-v2"],
)
def test_decode_matches_forward(arch):
    """prefill+decode token-by-token == teacher-forced forward."""
    cfg = get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", param_dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
        )  # dropless: capacity drops are the one train/decode divergence
    params = init_params(cfg, KEY)
    B, S, P = 2, 16, 8
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    enc_out = None
    if cfg.is_encdec:
        batch["src_embeds"] = jax.random.normal(KEY, (B, 8, cfg.frontend_dim))
        enc_out = encode(params, cfg, batch["src_embeds"])
    full, _ = forward_train(params, cfg, batch)
    cache = init_cache(cfg, B, S, enc_len=8 if cfg.is_encdec else 0,
                       dtype=jnp.float32)
    lp, cache = prefill(params, cfg, toks[:, :P], cache, enc_out=enc_out)
    errs = [float(jnp.abs(lp[:, 0] - full[:, P - 1]).max())]
    for t in range(P, S):
        ld, cache = decode_step(params, cfg, toks[:, t : t + 1], cache,
                                jnp.int32(t), enc_out=enc_out)
        errs.append(float(jnp.abs(ld[:, 0] - full[:, t]).max()))
    assert max(errs) < 2e-3, f"decode/forward mismatch: {max(errs)}"


def test_tiny_model_learns():
    """Loss decreases over a few steps on the structured synthetic stream."""
    from repro.data.pipeline import SyntheticDataset
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.step import make_train_step

    cfg = get_config("llama3.2-3b", reduced=True)
    params = init_params(cfg, KEY)
    oc = AdamWConfig(lr=5e-3)
    opt = adamw_init(params, oc)
    step = jax.jit(make_train_step(cfg, oc))
    ds = iter(SyntheticDataset(cfg.vocab_size, 32, 8, seed=0))
    losses = []
    for i in range(30):
        b = next(ds)
        params, opt, m = step(params, opt, b, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_mamba_chunked_vs_recurrent():
    """SSD chunked scan == step-by-step recurrence."""
    from repro.models.ssm import mamba_apply, mamba_decode, mamba_init

    cfg = get_config("mamba2-1.3b", reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", param_dtype="float32")
    p = mamba_init(jax.random.PRNGKey(3), cfg)
    B, S = 2, 20
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, cfg.d_model))
    y_full, cache_full = mamba_apply(p, x, cfg, return_cache=True)

    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    ch = di + 2 * s.n_groups * s.d_state
    cache = {
        "conv": jnp.zeros((B, s.d_conv - 1, ch)),
        "ssm": jnp.zeros((B, s.n_heads(cfg.d_model), s.d_state, s.head_dim)),
    }
    outs = []
    for t in range(S):
        o, cache = mamba_decode(p, x[:, t : t + 1], cfg, cache)
        outs.append(o)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_step), np.asarray(y_full), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(cache["ssm"]), np.asarray(cache_full["ssm"]),
        rtol=2e-4, atol=2e-4,
    )


def test_sable_ffn_model_runs_and_matches_pattern_flops():
    from repro.configs import llama3_8b

    cfg = llama3_8b.reduced_sable()
    params = init_params(cfg, KEY)
    w1 = params["groups"][0]["sub0"]["ffn"]["w1"]
    assert w1.ndim == 4  # (L, nt, tm, tk) — tiles, not dense
    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
    }
    logits, _ = forward_train(params, cfg, batch)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
