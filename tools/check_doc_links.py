#!/usr/bin/env python
"""Docs-link check: every relative markdown link must resolve to a file.

Scans tracked ``*.md`` files for ``[text](target)`` links, ignores absolute
URLs and pure anchors, and fails if a relative target (path resolved
against the containing file) does not exist.  Run from the repo root:

    python tools/check_doc_links.py
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", ".github", "__pycache__", ".ruff_cache", ".pytest_cache"}
# files quoting external repos verbatim — their relative links point elsewhere
SKIP_FILES = {"SNIPPETS.md", "PAPERS.md"}


def iter_markdown(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for name in filenames:
            if name.endswith(".md") and name not in SKIP_FILES:
                yield os.path.join(dirpath, name)


def main() -> int:
    root = os.getcwd()
    bad = []
    for path in iter_markdown(root):
        text = open(path, encoding="utf-8").read()
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
            if not os.path.exists(resolved):
                bad.append((os.path.relpath(path, root), target))
    if bad:
        for src, target in bad:
            print(f"BROKEN LINK: {src} -> {target}")
        return 1
    print("all markdown links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
