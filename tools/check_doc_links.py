#!/usr/bin/env python
"""Docs checks: markdown links AND inline code references must resolve.

Two passes over tracked ``*.md`` files:

1. **Links** — every relative ``[text](target)`` must point at a file that
   exists (path resolved against the containing file).
2. **Code references** — in the curated docs set (README.md, docs/*.md,
   benchmarks/README.md), inline code spans that *look like* repo paths
   (`` `src/repro/core/autotune.py` ``, `` `tools/check_doc_links.py` ``)
   must exist on disk, and dotted module references
   (`` `repro.core.autotune.measure` ``, `` `autotune.measure` `` where
   ``autotune`` is a module under ``src/repro``) must resolve to a module
   file whose text actually defines/mentions the symbol.  This catches the
   classic docs-drift failure: prose naming a helper that was renamed.

Spans inside fenced code blocks are ignored (they are examples, not
references), as are spans with spaces, placeholders (``<...>``, ``{...}``,
``...``), shell/flag syntax, and bare identifiers that don't name a repo
file — the check is deliberately conservative so it can run in CI without
false positives.  Run from the repo root:

    python tools/check_doc_links.py
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_SPAN_RE = re.compile(r"`([^`\n]+)`")
FENCE_RE = re.compile(r"^(```|~~~).*?^\1\s*$", re.MULTILINE | re.DOTALL)
SKIP_DIRS = {".git", ".github", "__pycache__", ".ruff_cache", ".pytest_cache"}
# files quoting external repos verbatim — their relative links point elsewhere
SKIP_FILES = {"SNIPPETS.md", "PAPERS.md"}
# only curated docs get the (stricter) code-reference pass; planning files
# (ISSUE/ROADMAP/CHANGES) legitimately reference not-yet-written code
CODE_REF_FILES = {"README.md", "benchmarks/README.md"}
CODE_REF_DIRS = {"docs"}

PATHLIKE_RE = re.compile(r"^[\w./-]+\.(py|md|json|yml|yaml|toml|sh)$")
# run artifacts docs legitimately name but which are never committed
GENERATED = {"BENCH_results.json"}
DOTTED_RE = re.compile(r"^[A-Za-z_][\w]*(\.[A-Za-z_][\w]*)+$")


def iter_markdown(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for name in filenames:
            if name.endswith(".md") and name not in SKIP_FILES:
                yield os.path.join(dirpath, name)


def wants_code_refs(relpath: str) -> bool:
    rel = relpath.replace(os.sep, "/")
    return rel in CODE_REF_FILES or rel.split("/", 1)[0] in CODE_REF_DIRS


def module_index(root: str) -> dict:
    """basename (sans .py) -> [paths] for every python file under src/."""
    idx: dict = {}
    for dirpath, dirnames, filenames in os.walk(os.path.join(root, "src")):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for name in filenames:
            if name.endswith(".py"):
                idx.setdefault(name[:-3], []).append(
                    os.path.join(dirpath, name)
                )
    return idx


def symbol_in(path: str, symbol: str) -> bool:
    try:
        text = open(path, encoding="utf-8").read()
    except OSError:
        return False
    return re.search(rf"\b{re.escape(symbol)}\b", text) is not None


def check_code_span(span: str, doc_dir: str, root: str, modules: dict):
    """None if the span is fine (resolves, or isn't a code reference)."""
    span = span.strip()
    # not a reference: spaces/placeholders/shell/flags/globs/env vars
    if (
        " " in span
        or any(c in span for c in "<>{}$*|=\"'")
        or span.startswith("-")
        or "..." in span
    ):
        return None
    span = span.rstrip(",;:")
    if span.endswith("()"):
        span = span[:-2]

    if os.path.basename(span) in GENERATED:
        return None
    if PATHLIKE_RE.match(span):
        for base in (doc_dir, root, os.path.join(root, "src"),
                     os.path.join(root, "src", "repro")):
            if os.path.exists(os.path.normpath(os.path.join(base, span))):
                return None
        # a bare filename (no slash) may live anywhere under src/
        if "/" not in span and span.endswith(".py") and span[:-3] in modules:
            return None
        return f"path `{span}` not found"

    if DOTTED_RE.match(span):
        parts = span.split(".")
        # repro.a.b.c — resolve the longest module-file prefix, then the
        # remainder must appear in that file (attribute / symbol)
        if parts[0] == "repro":
            base = os.path.join(root, "src")
            for cut in range(len(parts), 0, -1):
                mod = os.path.join(base, *parts[:cut])
                for cand in (mod + ".py", os.path.join(mod, "__init__.py")):
                    if os.path.exists(cand):
                        rest = parts[cut:]
                        if not rest or symbol_in(cand, rest[0]):
                            return None
                        return f"`{span}`: `{rest[0]}` not in {os.path.relpath(cand, root)}"
            return f"module `{span}` not found under src/"
        # module.symbol where `module` names a file under src/ (the docs'
        # shorthand, e.g. `autotune.measure`)
        if parts[0] in modules and len(parts) == 2:
            if any(symbol_in(p, parts[1]) for p in modules[parts[0]]):
                return None
            return f"`{span}`: `{parts[1]}` not in {parts[0]}.py"
    return None  # bare identifiers, CLI names, etc. — out of scope


def main() -> int:
    root = os.getcwd()
    modules = module_index(root)
    bad = []
    for path in iter_markdown(root):
        rel = os.path.relpath(path, root)
        text = open(path, encoding="utf-8").read()
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            t = target.split("#", 1)[0]
            if not t:
                continue
            resolved = os.path.normpath(os.path.join(os.path.dirname(path), t))
            if not os.path.exists(resolved):
                bad.append((rel, f"BROKEN LINK: {target}"))
        if wants_code_refs(rel):
            prose = FENCE_RE.sub("", text)
            for span in CODE_SPAN_RE.findall(prose):
                err = check_code_span(
                    span, os.path.dirname(path), root, modules
                )
                if err:
                    bad.append((rel, f"BROKEN CODE REF: {err}"))
    if bad:
        for src, msg in bad:
            print(f"{src}: {msg}")
        return 1
    print("all markdown links and code references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
